"""The examples are executable documentation: both must build *and run*
their apps end-to-end through the app compiler (not merely construct them).

Each example runs in a subprocess: they manipulate ``sys.path`` and print,
and ``examples/apps.py`` fork-pools JAX-touching workers — isolating them
keeps this test independent of the pytest process's own JAX state.
"""

import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_example(script: str, timeout: float = 900.0) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
    )
    proc = subprocess.run(
        [sys.executable, os.path.join(ROOT, "examples", script)],
        cwd=ROOT,
        env=env,
        capture_output=True,
        text=True,
        timeout=timeout,
    )
    assert proc.returncode == 0, (
        f"{script} exited {proc.returncode}\n--- stdout ---\n{proc.stdout}"
        f"\n--- stderr ---\n{proc.stderr}"
    )
    return proc.stdout


def test_quickstart_runs_composed_app_end_to_end():
    out = _run_example("quickstart.py")
    assert "Composed app1-missing-person" in out
    assert "OK: all events within gamma" in out
    # The dynamism epilogue: perturbed run with budget recovery + quality.
    assert "OK: budget recovered after the collapse." in out
    # The multi-query epilogue: two queries fused, one cancelled mid-run.
    assert "OK: multi-query tenancy" in out
    # The fault-tolerance epilogue: host crash + journaled restore, with the
    # recovered run bit-identical to the uninterrupted one.
    assert "OK: crash-and-restore" in out


def test_apps_executes_all_four_table1_apps():
    out = _run_example("apps.py")
    # All four Table-1 apps ran end-to-end through compile_app + SweepRunner.
    for name in ("app1", "app2", "app3", "app4"):
        assert f"  {name}: events=" in out, out
    assert "Composed 4 tracking applications" in out
    assert "JAX end to end" in out
