"""Dynamism plane: perturbation composition, telemetry, tracking quality,
and the frozen golden trace (satellite of the dynamism-plane PR).

The golden digest below was recorded at this PR's commit for seed 0 and must
replay bit-identically (mirroring the frozen-summary pattern in
``tests/test_compile.py``): the trace is a pure function of (config, spec),
so any drift in the event runtime, the budget protocol, the perturbation
plumbing or the telemetry sampling changes it loudly.
"""

import math

import pytest

from repro.sim import (
    BandwidthCollapse,
    CameraChurn,
    ComputeSlowdown,
    DynamismSpec,
    InputRateSpike,
    ScenarioConfig,
    TrackingScenario,
    fig9_collapse,
)

# --------------------------------------------------------------------- #
# The golden Fig.-9-style bandwidth-collapse run (seed 0): 300 cameras,   #
# 150 s, collapse over [50, 90), dynamic batching, drops on.              #
# --------------------------------------------------------------------- #
GOLDEN_SPEC = DynamismSpec((BandwidthCollapse(50.0, 90.0, 2e-5),))
GOLDEN_DIGEST = "1e90992d1844ad60402c31575e2bff056b00a8ecf6c1e117b6e65c8caaa8c977"
GOLDEN_SUMMARY = {
    "source_events": 1991, "on_time": 970, "delayed": 2, "dropped": 1019,
    "delayed_frac": 0.0021, "dropped_frac": 0.5118,
    "median_latency_s": 8.488, "p99_latency_s": 14.663, "peak_active": 41,
    "positives_generated": 31, "positives_completed": 23,
    "samples": 40, "beta_pre": 12.2133, "beta_low": 1.4281,
    "beta_post": 14.9022, "beta_recovery": 1.2202, "peak_queue": 21,
    "probes": 53, "truth_events": 31, "track_recall": 0.7419,
    "track_precision": 1.0,
}


def _cfg(**kw):
    base = dict(num_cameras=300, duration_s=150.0, seed=0, tl="bfs",
                batching="dynamic", m_max=25)
    base.update(kw)
    return ScenarioConfig(**base)


def _golden_cfg():
    return _cfg(drops_enabled=True, avoid_drop_positives=True,
                dynamism=GOLDEN_SPEC)


@pytest.fixture(scope="module")
def golden_run():
    return TrackingScenario(_golden_cfg()).run()


def test_golden_trace_bit_identical_replay(golden_run):
    """The frozen seed-0 bandwidth-collapse trace replays bit-for-bit."""
    assert golden_run.summary() == GOLDEN_SUMMARY
    assert golden_run.trace.digest() == GOLDEN_DIGEST
    # And a second independent run reproduces the digest (replayability is
    # a property of the run, not of one lucky recording).
    again = TrackingScenario(_golden_cfg()).run()
    assert again.trace.digest() == GOLDEN_DIGEST


def test_golden_trace_shows_collapse_drop_wave_and_recovery(golden_run):
    """The qualitative Fig.-9 story, as the trace actually records it:

    * the CR budget collapses (bootstrap-era, §4.5) and probes recover it —
      the trace-wide ``low`` is a fraction of the settled pre-window value;
    * the bandwidth window's effect is a *drop wave* — late events die at
      the upstream drop points at well over the undisturbed rate (the
      upstream drops shield CR, so its beta series stays flat in-window);
    * the budget ends within 10% of its pre-perturbation value, and the
      dynamic batcher grew batches well past streaming along the way.
    """
    trace = golden_run.trace
    rec = trace.budget_recovery("CR")
    assert rec["low"] < 0.2 * rec["pre"], "budget collapse + recovery missing"
    assert rec["recovery"] >= 0.9, "dynamic batcher must recover its budget"
    spec = trace.spec.perturbations[0]
    in_window = trace.dropped_between(spec.t_start, spec.t_end)
    before = trace.dropped_between(
        spec.t_start - (spec.t_end - spec.t_start), spec.t_start
    )
    assert in_window > 1.5 * before, "collapse must cause a drop wave"
    assert max(trace.mean_batch("CR")) > 5.0, "batch-size growth missing"
    assert sum(
        row["probes"][-1] for row in trace.series.values() if row["probes"]
    ) > 0, "recovery must have been probe-driven"


# --------------------------------------------------------------------- #
# Composition                                                            #
# --------------------------------------------------------------------- #
def test_spec_multipliers_compose_multiplicatively():
    spec = DynamismSpec((
        BandwidthCollapse(10.0, 20.0, 0.5),
        BandwidthCollapse(15.0, 30.0, 0.2),
        ComputeSlowdown(10.0, 20.0, 3.0, hosts=("node0",)),
        ComputeSlowdown(15.0, 30.0, 2.0),
        InputRateSpike(5.0, 25.0, 4.0),
    ))
    bw = spec.bandwidth_schedule()
    assert bw(5.0) == 1.0
    assert bw(12.0) == 0.5
    assert bw(17.0) == pytest.approx(0.1)   # overlap: 0.5 * 0.2
    assert bw(25.0) == 0.2
    xi = spec.xi_multiplier()
    assert xi("node0", 17.0) == pytest.approx(6.0)  # 3.0 * 2.0
    assert xi("node1", 17.0) == 2.0                  # host-filtered
    assert xi("node0", 35.0) == 1.0
    rate = spec.rate_multiplier()
    assert rate(10.0) == 4.0 and rate(30.0) == 1.0
    # Composition over an explicit base schedule (the config's own Fig. 9).
    assert spec.bandwidth_schedule(lambda t: 0.5)(12.0) == pytest.approx(0.25)


def test_empty_spec_installs_nothing():
    spec = DynamismSpec()
    assert spec.bandwidth_schedule() is None
    assert spec.xi_multiplier() is None
    assert spec.rate_multiplier() is None
    assert spec.churns() == ()


def test_fig9_collapse_helper():
    spec = fig9_collapse()
    assert spec.bandwidth_schedule()(299.0) == 1.0
    assert spec.bandwidth_schedule()(301.0) == 0.03


def test_undisturbed_run_carries_no_trace_or_extras():
    res = TrackingScenario(_cfg(duration_s=30.0)).run()
    assert res.trace is None and res.quality is None
    assert "beta_recovery" not in res.summary()
    assert "track_recall" not in res.summary()


# --------------------------------------------------------------------- #
# Individual perturbations through the compiled pipeline                  #
# --------------------------------------------------------------------- #
def test_compute_slowdown_inflates_latency():
    base = TrackingScenario(_cfg(duration_s=60.0)).run()
    slow = TrackingScenario(_cfg(
        duration_s=60.0,
        dynamism=DynamismSpec((ComputeSlowdown(0.0, math.inf, 20.0, hosts=("node",)),)),
    )).run()
    # Same workload (the walk and spotlight don't depend on xi)...
    assert slow.source_events == base.source_events
    # ...but every VA/CR execution takes 20x longer.
    assert slow.median_latency > 3.0 * base.median_latency


def test_compute_slowdown_disables_fusion_but_not_correctness():
    cfg = _cfg(duration_s=60.0, dynamism=DynamismSpec(
        (ComputeSlowdown(1e9, math.inf, 5.0),)  # window never opens
    ))
    sc = TrackingScenario(cfg)
    assert not sc.compiled.fuse_fc  # dynamic-xi regime: fusion off
    res = sc.run()
    base = TrackingScenario(_cfg(duration_s=60.0)).run()
    # A multiplier whose window never opens is identity: same counters.
    assert res.source_events == base.source_events
    assert res.on_time == base.on_time
    assert res.delayed == base.delayed


def test_input_rate_slowdown_resumes_after_window():
    """A sub-1 rate factor stretches the tick interval; the tick must be
    clamped to the window edge so sourcing resumes when it closes instead
    of overshooting past the end of the run (a permanent stall)."""
    base = TrackingScenario(_cfg(duration_s=150.0)).run()
    slowed = TrackingScenario(_cfg(
        duration_s=150.0,
        dynamism=DynamismSpec((InputRateSpike(50.0, 60.0, 0.001),)),
    )).run()
    # The 10 s window goes quiet, but the other 140 s source normally.
    assert slowed.source_events > 0.7 * base.source_events
    assert max(t for t, _ in slowed.latencies) > 60.0, "sourcing never resumed"


def test_xi_multiplier_installed_after_build_raises():
    """Tasks snapshot the multiplier at construction; a late install would
    silently scale nothing, so the simulator refuses it."""
    sc = TrackingScenario(_cfg(duration_s=10.0))
    with pytest.raises(RuntimeError):
        sc.sim.xi_multiplier = lambda host, t: 2.0


def test_input_rate_spike_raises_source_events():
    base = TrackingScenario(_cfg(duration_s=60.0)).run()
    spiked = TrackingScenario(_cfg(
        duration_s=60.0,
        dynamism=DynamismSpec((InputRateSpike(20.0, 40.0, 3.0),)),
    )).run()
    assert spiked.source_events > 1.5 * base.source_events


def test_camera_churn_is_seeded_and_dents_the_active_set():
    spec = DynamismSpec((CameraChurn(period_s=5.0, fraction=0.5,
                                     outage_s=4.0, seed=3),))
    cfg = _cfg(duration_s=60.0, dynamism=spec)
    a = TrackingScenario(cfg).run()
    b = TrackingScenario(cfg).run()
    # Seeded churn is replayable...
    assert a.trace.digest() == b.trace.digest()
    assert a.summary() == b.summary()
    # ...and actually takes cameras down: fewer sourced frames than the
    # undisturbed run, and the entity is missed more often.
    base = TrackingScenario(_cfg(duration_s=60.0)).run()
    assert a.source_events < base.source_events
    assert a.positives_generated <= base.positives_generated


def test_perturbations_validate_at_construction():
    with pytest.raises(ValueError):
        InputRateSpike(factor=0.0)   # would stall the source clock
    with pytest.raises(ValueError):
        ComputeSlowdown(factor=-1.0)
    with pytest.raises(ValueError):
        BandwidthCollapse(factor=0.0)
    with pytest.raises(ValueError):
        CameraChurn(period_s=0.0)
    with pytest.raises(ValueError):
        CameraChurn(fraction=1.5)
    with pytest.raises(ValueError):
        CameraChurn(outage_s=-1.0)


def test_camera_churn_zero_fraction_is_the_undisturbed_baseline():
    """fraction=0 on a sweep axis must mean *no* churn, not one camera."""
    base = TrackingScenario(_cfg(duration_s=60.0)).run()
    zero = TrackingScenario(_cfg(
        duration_s=60.0,
        dynamism=DynamismSpec((CameraChurn(period_s=5.0, fraction=0.0),)),
    )).run()
    assert zero.source_events == base.source_events
    assert zero.on_time == base.on_time


def test_camera_churn_window_shorter_than_period_still_fires():
    """The first churn tick lands at t_start, so a window narrower than
    period_s darkens cameras exactly once instead of silently never."""
    spec = DynamismSpec((CameraChurn(period_s=20.0, fraction=1.0,
                                     outage_s=6.0, t_start=30.0, t_end=33.0),))
    base = TrackingScenario(_cfg(duration_s=60.0)).run()
    churned = TrackingScenario(_cfg(duration_s=60.0, dynamism=spec)).run()
    assert churned.source_events < base.source_events
    # The whole wanted set went dark at t=30: the active series dips to 0.
    trace = churned.trace
    dipped = [c for t, c in zip(trace.times, trace.active_cameras)
              if 30.0 <= t < 36.0]
    assert dipped and min(dipped) == 0


def test_bandwidth_collapse_composes_with_config_schedule():
    """A config-level Fig.-9 schedule and a spec-level collapse multiply."""
    cfg = _cfg(
        duration_s=30.0,
        bandwidth_schedule=lambda t: 0.5,
        dynamism=DynamismSpec((BandwidthCollapse(0.0, math.inf, 0.5),),
                              telemetry_period_s=0.0, quality=False),
    )
    sc = TrackingScenario(cfg)
    assert sc.sim.network.bandwidth_schedule(10.0) == pytest.approx(0.25)
    assert not sc.sim.transit_is_static


# --------------------------------------------------------------------- #
# Telemetry + quality harness                                            #
# --------------------------------------------------------------------- #
def test_telemetry_samples_every_module_and_cadence(golden_run):
    trace = golden_run.trace
    cfg = _golden_cfg()
    names = set(trace.series)
    assert {f"VA-{i}" for i in range(cfg.num_va)} <= names
    assert {f"CR-{i}" for i in range(cfg.num_cr)} <= names
    assert "UV" in names and "FC*" in names
    n = len(trace.times)
    assert n == len(trace.active_cameras)
    for row in trace.series.values():
        assert all(len(col) == n for col in row.values())
    # Cadence: strictly increasing sample times (the final drain sample
    # replaces, never duplicates, a same-timestamp tick), 5 s apart.
    deltas = [round(b - a, 6) for a, b in zip(trace.times, trace.times[1:])]
    assert all(0.0 < d <= 5.0 for d in deltas)
    # Cumulative counters never decrease.
    for row in trace.series.values():
        for fld in ("dp1", "dp2", "dp3", "probes", "accepts", "rejects",
                    "batches", "executed"):
            col = row[fld]
            assert all(x <= y for x, y in zip(col, col[1:]))


def test_quality_metrics_without_drops_match_completion_accounting():
    """With drops off and a pass-through pipeline every ground-truth frame
    the spotlight sourced completes, so recall is completed/truth and the
    preset CR (no false positives) gives precision 1.0."""
    res = TrackingScenario(_cfg(
        duration_s=90.0,
        dynamism=DynamismSpec(telemetry_period_s=0.0),
    )).run()
    assert res.trace is None and res.quality is not None
    q = res.quality
    assert q["track_precision"] == 1.0
    assert q["truth_events"] >= res.positives_generated
    assert q["track_recall"] == pytest.approx(
        res.positives_completed / q["truth_events"], abs=1e-4
    )


def test_telemetry_only_spec_keeps_trajectory_identical():
    """A spec with no perturbations only *observes*: every counter of the
    undisturbed run is reproduced exactly (the telemetry tick must not
    perturb event ordering)."""
    base = TrackingScenario(_cfg(duration_s=60.0)).run()
    observed = TrackingScenario(_cfg(
        duration_s=60.0, dynamism=DynamismSpec()
    )).run()
    for key in ("source_events", "on_time", "delayed", "dropped",
                "positives_generated", "positives_completed"):
        assert base.summary()[key] == observed.summary()[key], key
    assert observed.trace is not None
    assert observed.trace.summary()["samples"] > 0
