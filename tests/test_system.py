"""End-to-end system behaviours: pipeline signal mechanics, probe recovery,
the Table-1 app compositions, and generator determinism."""

import math
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.batching import DynamicBatcher
from repro.core.budget import TaskBudget
from repro.core.clock import Clock
from repro.core.events import Event, EventHeader, new_event_id
from repro.core.pipeline import SinkTask, Task
from repro.core.roadnet import make_road_network
from repro.sim.simulator import DiscreteEventSimulator, NetworkModel


def xi_fast(b):
    return 0.01 + 0.005 * b


def xi_slow(b):
    return 0.05 + 0.02 * b


def build_pipeline(sim, gamma=2.0, drops=True):
    sink = SinkTask("UV", sim, gamma=gamma, epsilon_max=0.05, node="head")
    t2 = Task("T2", sim, xi_slow, DynamicBatcher(xi_slow, m_max=8),
              budget=TaskBudget("T2", xi_slow, m_max=8),
              drops_enabled=drops, node="n1")
    t1 = Task("T1", sim, xi_fast, DynamicBatcher(xi_fast, m_max=8),
              budget=TaskBudget("T1", xi_fast, m_max=8),
              drops_enabled=drops, node="n0")
    t1.connect(t2)
    t2.connect(sink)
    t2.partitioner = lambda ev: "UV"
    t1.partitioner = lambda ev: "T2"
    return t1, t2, sink


def feed(sim, t1, n, rate_hz=20.0):
    def emit(i):
        ev = Event(header=EventHeader(event_id=new_event_id(),
                                      source_arrival=sim.time), key=i)
        t1.on_arrival(ev)

    for i in range(n):
        sim.schedule(i / rate_hz, lambda i=i: emit(i))


def test_pipeline_bootstraps_then_learns_budgets():
    sim = DiscreteEventSimulator(NetworkModel())
    t1, t2, sink = build_pipeline(sim)
    feed(sim, t1, 40)
    sim.run(until=30.0)
    assert sink.stats.arrived >= 30
    # accept signals initialized the budgets from infinity
    assert not math.isinf(t2.budget.min_budget())
    assert not math.isinf(t1.budget.min_budget())


def test_probe_recovers_collapsed_budget():
    """Force a collapsed budget; probes (forwarded un-droppably) must reach
    the sink and raise it again (§4.5.2)."""
    sim = DiscreteEventSimulator(NetworkModel())
    t1, t2, sink = build_pipeline(sim)
    t1.probe_every = 2
    t1.budget.set_budget(-1.0, downstream="T2")  # collapse: everything drops
    feed(sim, t1, 60, rate_hz=30.0)
    sim.run(until=30.0)
    assert t1.stats.dropped > 0, "collapsed budget must drop"
    # probe-led accepts raised the budget back above the collapse value
    assert t1.budget.budget("T2") > -1.0
    assert sink.stats.arrived > 0  # probes reached the sink


def test_avoid_drop_event_survives_collapsed_budget():
    sim = DiscreteEventSimulator(NetworkModel())
    t1, t2, sink = build_pipeline(sim)
    t1.budget.set_budget(-1.0, downstream="T2")
    t2.budget.set_budget(-1.0, downstream="UV")
    protected = Event(
        header=EventHeader(event_id=new_event_id(), source_arrival=0.0, avoid_drop=True),
        key="vip",
    )
    sim.schedule(0.0, lambda: t1.on_arrival(protected))
    sim.run(until=10.0)
    assert sink.stats.arrived >= 1


def test_road_network_deterministic():
    from repro.core import roadnet

    a = make_road_network(num_vertices=200, target_edges=560, seed=5)
    # Identical parameters return a shared cached instance; clear the cache
    # so the second call genuinely reconstructs the graph.
    roadnet._NETWORK_CACHE.clear()
    b = make_road_network(num_vertices=200, target_edges=560, seed=5)
    assert a is not b
    np.testing.assert_array_equal(a.positions, b.positions)
    assert a.adjacency == b.adjacency


def test_table1_apps_compose():
    sys.path.insert(0, "examples")
    import apps as apps_mod

    apps = apps_mod.build_apps()
    assert [a.name for a in apps] == ["app1", "app2", "app3", "app4"]
    assert apps[1].qf is not None  # App 2 has query fusion
    assert type(apps[3].tl).__name__ == "TLProbabilistic"
    # App 4's VA runs a real JAX tower end to end.
    frames = np.zeros((3, 128), np.float32)
    out = apps[3].va(0, list(frames), {"entity_query": np.zeros((1, 32), np.float32)})
    assert len(out) == 3


def test_generator_is_deterministic():
    from repro.config import get_config
    from repro.models import init_params, reduced_config
    from repro.serving import Generator

    cfg = reduced_config(get_config("llama3.2-1b"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    gen = Generator(cfg, params)
    prompts = jnp.ones((1, 8), jnp.int32)
    a = gen.generate(prompts, max_new_tokens=5)
    b = gen.generate(prompts, max_new_tokens=5)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
