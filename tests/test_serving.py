"""Serving engine + Anveshak-scheduled stages."""

import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_config
from repro.models import init_params, reduced_config
from repro.serving import (
    Generator,
    ServedStage,
    StageRequest,
    bucket_for,
    calibrate_xi,
    embed_frames,
    init_reid_tower,
    match,
    sample_tokens,
)


def test_bucket_for():
    assert bucket_for(1) == 1
    assert bucket_for(3) == 4
    assert bucket_for(100) == 128
    assert bucket_for(10_000) == 128  # clamped to largest


def test_sampling_greedy_and_masked():
    logits = jnp.asarray([[0.0, 5.0, 1.0, 9.0]])
    rng = jax.random.PRNGKey(0)
    assert int(sample_tokens(logits, rng, greedy=True)[0]) == 3
    # padded-vocab mask: index 3 is out of the real vocab
    assert int(sample_tokens(logits, rng, greedy=True, vocab_size=3)[0]) == 1


def test_generator_decodes_consistently_with_forward():
    from repro.models import forward

    cfg = reduced_config(get_config("llama3.2-1b"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    gen = Generator(cfg, params)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab_size)
    out = gen.generate(prompts, max_new_tokens=4)
    assert out.shape == (2, 4)
    # first generated token == argmax of the forward logits at the prompt end
    logits, _ = forward(params, cfg, {"tokens": prompts})
    expect = jnp.argmax(logits[:, -1, : cfg.vocab_size], axis=-1)
    np.testing.assert_array_equal(np.asarray(out[:, 0]), np.asarray(expect))


class TestServedStage:
    def make_stage(self, gamma=5.0, drops=True):
        tower = init_reid_tower(jax.random.PRNGKey(1), d_in=64, d_embed=64)
        step = lambda x: embed_frames(tower, jnp.asarray(x))
        xi = calibrate_xi(step, (64,), buckets=(1, 4, 16), repeats=1)
        return ServedStage(
            "CR", step, xi, gamma=gamma, m_max=16, buckets=(1, 4, 16), drops_enabled=drops
        )

    def test_processes_requests(self):
        stage = self.make_stage()
        results = []
        for _ in range(8):
            r = stage.submit(StageRequest(np.random.randn(64).astype(np.float32),
                                          source_time=stage.clock()))
            if r:
                results.extend(r)
        r = stage.flush()
        if r:
            results.extend(r)
        done = [x for x in results if not x.dropped]
        assert len(done) >= 1
        assert all(x.output is not None and x.output.shape == (64,) for x in done)

    def test_drops_stale_requests(self):
        stage = self.make_stage(gamma=0.5)
        # Teach the budget a small value via a reject-style path: directly
        # install a budget so DP1 has something to compare against.
        stage.budget.set_budget(0.01)
        stale = StageRequest(
            np.zeros(64, np.float32), source_time=stage.clock() - 10.0
        )
        res = stage.submit(stale)
        assert res is not None and res[0].dropped

    def test_avoid_drop_protects(self):
        stage = self.make_stage(gamma=0.5)
        stage.budget.set_budget(0.01)
        protected = StageRequest(
            np.zeros(64, np.float32), source_time=stage.clock() - 10.0, avoid_drop=True
        )
        res = stage.submit(protected)
        done = (res or []) + (stage.flush() or [])
        assert all(not r.dropped for r in done)

    def test_telemetry_snapshot_mirrors_dynamism_trace_fields(self):
        """ServedStage exposes the same telemetry row the discrete-event
        plane's DynamismTrace samples (budget, queue, the three drop-point
        counters, signal counters) — one tracing surface for both planes."""
        from repro.sim.dynamism import TRACE_FIELDS

        stage = self.make_stage()
        t0 = stage.telemetry()
        assert set(t0) == set(TRACE_FIELDS)
        assert t0["dp1"] == t0["dp2"] == t0["dp3"] == 0
        # A DP1 drop shows up in the split AND keeps the "dropped" total.
        stage.budget.set_budget(0.01)
        stage.submit(StageRequest(np.zeros(64, np.float32),
                                  source_time=stage.clock() - 10.0))
        t1 = stage.telemetry()
        assert t1["dp1"] == 1 and stage.stats["dropped"] == 1
        # Signals land in the counters the trace samples.
        stage.on_accept(event_id=123, epsilon=1.0, xi_bar=0.5)
        stage.on_reject(event_id=124, epsilon=1.0, q_bar=0.5)
        t2 = stage.telemetry()
        assert t2["accepts"] == 1 and t2["rejects"] == 1
        assert t2["beta"] == stage.budget.min_budget()

    def test_telemetry_query_id_dimension(self):
        """Multi-query serving: per-query rows share the sim plane's
        TRACE_FIELDS shape, counters split by StageRequest.query_id, and
        drops are charged to the owning query."""
        from repro.sim.dynamism import TRACE_FIELDS

        stage = self.make_stage(drops=False)
        for qid in (7, 7, 9, None):
            res = stage.submit(
                StageRequest(
                    np.zeros(64, np.float32),
                    source_time=stage.clock(),
                    query_id=qid,
                )
            )
        stage.flush()
        assert stage.query_ids() == [7, 9]
        t7, t9 = stage.telemetry(7), stage.telemetry(9)
        assert set(t7) == set(TRACE_FIELDS)
        assert t7["executed"] == 2 and t9["executed"] == 1
        # The stage-wide row still counts everything (incl. untagged).
        assert stage.telemetry()["executed"] == 4
        # A DP1 drop lands in the owning query's row only.
        stage2 = self.make_stage()
        stage2.budget.set_budget(0.01)
        stage2.submit(
            StageRequest(
                np.zeros(64, np.float32),
                source_time=stage2.clock() - 10.0,
                query_id=3,
            )
        )
        assert stage2.telemetry(3)["dp1"] == 1
        assert stage2.telemetry(4)["dp1"] == 0

    def test_publish_metrics_bridges_telemetry_rows(self):
        """The obs bridge re-emits stage-wide and per-query telemetry rows
        as registered WALL-domain metrics: every TRACE_FIELDS column lands
        either in the gauge (beta/queue) or the counter (the rest), values
        match ``telemetry()`` exactly, and nothing serving-side leaks into
        the SIM determinism digest."""
        from repro.obs import SIM, MetricsRegistry
        from repro.sim.dynamism import TRACE_FIELDS

        stage = self.make_stage(drops=False)
        for qid in (7, 7, 9, None):
            stage.submit(
                StageRequest(
                    np.zeros(64, np.float32),
                    source_time=stage.clock(),
                    query_id=qid,
                )
            )
        stage.flush()
        reg = MetricsRegistry()
        stage.publish_metrics(reg)
        row = stage.telemetry()
        sev = reg.get("repro_stage_events_total")
        sgauge = reg.get("repro_stage_row")
        for fld in TRACE_FIELDS:
            if fld in ("beta", "queue"):
                assert sgauge.value(stage="CR", field=fld) == row[fld]
            elif row[fld]:
                assert sev.value(stage="CR", kind=fld) == row[fld]
        q7 = stage.telemetry(query_id=7)
        qev = reg.get("repro_stage_query_events_total")
        assert qev.value(stage="CR", query="7", kind="executed") == q7["executed"]
        assert reg.get("repro_stage_query_row").value(
            stage="CR", query="9", field="beta"
        ) == stage.telemetry(query_id=9)["beta"]
        # Serving metrics are wall-domain: the SIM digest must not see them.
        assert not any(m.domain == SIM for m in reg.collect())
        assert "repro_stage" not in reg.exposition(include_wall=False)

    def test_query_major_bucket_padding(self):
        """set_queries pads the live-query block to a power-of-two bucket
        and the step runs query-major: one device call serves every query,
        and growing within the bucket never changes the padded shape."""
        shapes = []

        def step(x, qblock, nq):
            shapes.append((x.shape, tuple(qblock.shape), int(nq)))
            return jnp.asarray(x)

        stage = ServedStage(
            "VA", step, lambda b: 0.0001 * b, gamma=5.0, m_max=4,
            buckets=(1, 4), drops_enabled=False,
        )
        stage.set_queries(np.ones((3, 16), np.float32))
        stage.submit(StageRequest(np.zeros(16, np.float32),
                                  source_time=stage.clock()))
        stage.flush()
        assert shapes and shapes[-1][1] == (8, 16)  # 3 -> bucket(3) == 8
        assert shapes[-1][2] == 3
        stage.set_queries(np.ones((5, 16), np.float32))
        stage.submit(StageRequest(np.zeros(16, np.float32),
                                  source_time=stage.clock()))
        stage.flush()
        assert shapes[-1][1] == (8, 16) and shapes[-1][2] == 5
        # Empty block falls back to the single-query step signature.
        stage.set_queries(np.zeros((0, 16), np.float32))
        assert stage._query_block is None


def test_reid_match_pipeline():
    tower = init_reid_tower(jax.random.PRNGKey(2), d_in=32, d_embed=16)
    frames = jax.random.normal(jax.random.PRNGKey(3), (20, 32))
    query = embed_frames(tower, frames[5:6])
    scores, best, is_match = match(tower, frames, query, threshold=0.999)
    assert bool(is_match[5])
    assert int(jnp.argmax(scores)) == 5


# --------------------------------------------------------------------- #
# App-compiler lowering: one spec, two planes                            #
# --------------------------------------------------------------------- #
class TestLowerAppStages:
    def _app(self, **specs):
        from repro.core.dataflow import ModuleSpec, TrackingApp, fc_is_active
        from repro.core.roadnet import make_road_network
        from repro.core.tracking import TLBase

        road = make_road_network(num_vertices=30, target_edges=84, seed=0)
        return TrackingApp(
            name="served",
            fc=fc_is_active,
            va=lambda c, f, s: [(c, x) for x in f],
            cr=lambda c, v, s: [(c, x) for x in v],
            tl=TLBase(road, {0: 0}),
            gamma=0.75,
            specs=specs,
        )

    def test_stages_resolve_from_app_and_deployment(self):
        from repro.core.compile import DeploymentSpec, linear_xi
        from repro.core.dataflow import ModuleSpec
        from repro.serving import lower_app_stages

        app = self._app(
            VA=ModuleSpec(m_max=8, xi=linear_xi(0.001, 0.0005)),
            CR=ModuleSpec(m_max=4, xi=linear_xi(0.002, 0.001)),
        )
        stages = lower_app_stages(
            app,
            DeploymentSpec(drops_enabled=True),
            {"VA": lambda x: x, "CR": lambda x: x * 2},
        )
        va, cr = stages["VA"], stages["CR"]
        assert va.name == "served/VA" and cr.name == "served/CR"
        assert va.gamma == cr.gamma == 0.75  # app QoS, both planes
        assert va.batcher.m_max == 8 and cr.batcher.m_max == 4
        assert va.drops_enabled and cr.drops_enabled
        assert cr.upstream is va  # reject/accept chain VA <- CR
        assert va.xi(2) == pytest.approx(0.002)
        # The stages actually serve: submit one request through VA.
        res = va.submit(StageRequest(np.ones(4, np.float32), source_time=va.clock()))
        assert res and not res[0].dropped
        np.testing.assert_allclose(res[0].output, np.ones(4, np.float32))

    def test_non_dynamic_batching_is_rejected(self):
        from repro.core.compile import DeploymentSpec, linear_xi
        from repro.core.dataflow import ModuleSpec
        from repro.serving import lower_stage

        app = self._app(VA=ModuleSpec(batching="static", xi=linear_xi(0.001, 0.0)))
        with pytest.raises(ValueError, match="dynamic"):
            lower_stage("VA", app, DeploymentSpec(), lambda x: x)

    def test_missing_cost_model_calibrates_from_step(self):
        from repro.core.compile import DeploymentSpec, linear_xi
        from repro.core.dataflow import ModuleSpec
        from repro.serving import lower_stage

        app = self._app()  # no xi anywhere
        with pytest.raises(ValueError, match="payload_shape"):
            lower_stage("VA", app, DeploymentSpec(), lambda x: x)
        stage = lower_stage(
            "VA", app, DeploymentSpec(), lambda x: x,
            payload_shape=(4,), buckets=(1, 2),
        )
        assert stage.xi(1) > 0.0  # measured, monotone-ish cost model
        # An *explicit* zero cost model is a declaration, not an absence:
        # it must be honored, never overridden by calibration.
        free = self._app(VA=ModuleSpec(xi=linear_xi(0.0, 0.0)))
        stage = lower_stage("VA", free, DeploymentSpec(), lambda x: x)
        assert stage.xi(8) == 0.0

    def test_cr_drop_rejects_into_va_budget(self):
        """The VA <- CR signal chain is live: a CR-side drop calls the VA
        stage's on_reject with the lateness epsilon."""
        from repro.core.compile import DeploymentSpec, linear_xi
        from repro.core.dataflow import ModuleSpec
        from repro.serving import lower_app_stages

        app = self._app(
            VA=ModuleSpec(xi=linear_xi(0.001, 0.0)),
            CR=ModuleSpec(xi=linear_xi(0.001, 0.0)),
        )
        stages = lower_app_stages(
            app, DeploymentSpec(drops_enabled=True),
            {"VA": lambda x: x, "CR": lambda x: x},
        )
        va, cr = stages["VA"], stages["CR"]
        rejects = []
        va.on_reject = lambda eid, eps, q_bar: rejects.append((eid, eps, q_bar))
        # Teach CR a finite budget, then submit a hopelessly stale request:
        # DP1 drops it and the reject must reach the VA hook.
        cr.budget.set_budget(0.05)
        res = cr.submit(
            StageRequest(np.zeros(4, np.float32), source_time=cr.clock() - 10.0)
        )
        assert res and res[0].dropped
        assert len(rejects) == 1
        eid, eps, _ = rejects[0]
        assert eid == res[0].event_id and eps > 0.0
