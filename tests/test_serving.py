"""Serving engine + Anveshak-scheduled stages."""

import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_config
from repro.models import init_params, reduced_config
from repro.serving import (
    Generator,
    ServedStage,
    StageRequest,
    bucket_for,
    calibrate_xi,
    embed_frames,
    init_reid_tower,
    match,
    sample_tokens,
)


def test_bucket_for():
    assert bucket_for(1) == 1
    assert bucket_for(3) == 4
    assert bucket_for(100) == 128
    assert bucket_for(10_000) == 128  # clamped to largest


def test_sampling_greedy_and_masked():
    logits = jnp.asarray([[0.0, 5.0, 1.0, 9.0]])
    rng = jax.random.PRNGKey(0)
    assert int(sample_tokens(logits, rng, greedy=True)[0]) == 3
    # padded-vocab mask: index 3 is out of the real vocab
    assert int(sample_tokens(logits, rng, greedy=True, vocab_size=3)[0]) == 1


def test_generator_decodes_consistently_with_forward():
    from repro.models import forward

    cfg = reduced_config(get_config("llama3.2-1b"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    gen = Generator(cfg, params)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab_size)
    out = gen.generate(prompts, max_new_tokens=4)
    assert out.shape == (2, 4)
    # first generated token == argmax of the forward logits at the prompt end
    logits, _ = forward(params, cfg, {"tokens": prompts})
    expect = jnp.argmax(logits[:, -1, : cfg.vocab_size], axis=-1)
    np.testing.assert_array_equal(np.asarray(out[:, 0]), np.asarray(expect))


class TestServedStage:
    def make_stage(self, gamma=5.0, drops=True):
        tower = init_reid_tower(jax.random.PRNGKey(1), d_in=64, d_embed=64)
        step = lambda x: embed_frames(tower, jnp.asarray(x))
        xi = calibrate_xi(step, (64,), buckets=(1, 4, 16), repeats=1)
        return ServedStage(
            "CR", step, xi, gamma=gamma, m_max=16, buckets=(1, 4, 16), drops_enabled=drops
        )

    def test_processes_requests(self):
        stage = self.make_stage()
        results = []
        for _ in range(8):
            r = stage.submit(StageRequest(np.random.randn(64).astype(np.float32),
                                          source_time=stage.clock()))
            if r:
                results.extend(r)
        r = stage.flush()
        if r:
            results.extend(r)
        done = [x for x in results if not x.dropped]
        assert len(done) >= 1
        assert all(x.output is not None and x.output.shape == (64,) for x in done)

    def test_drops_stale_requests(self):
        stage = self.make_stage(gamma=0.5)
        # Teach the budget a small value via a reject-style path: directly
        # install a budget so DP1 has something to compare against.
        stage.budget.set_budget(0.01)
        stale = StageRequest(
            np.zeros(64, np.float32), source_time=stage.clock() - 10.0
        )
        res = stage.submit(stale)
        assert res is not None and res[0].dropped

    def test_avoid_drop_protects(self):
        stage = self.make_stage(gamma=0.5)
        stage.budget.set_budget(0.01)
        protected = StageRequest(
            np.zeros(64, np.float32), source_time=stage.clock() - 10.0, avoid_drop=True
        )
        res = stage.submit(protected)
        done = (res or []) + (stage.flush() or [])
        assert all(not r.dropped for r in done)


def test_reid_match_pipeline():
    tower = init_reid_tower(jax.random.PRNGKey(2), d_in=32, d_embed=16)
    frames = jax.random.normal(jax.random.PRNGKey(3), (20, 32))
    query = embed_frames(tower, frames[5:6])
    scores, best, is_match = match(tower, frames, query, threshold=0.999)
    assert bool(is_match[5])
    assert int(jnp.argmax(scores)) == 5
