"""Tracking logic + road network (paper §2.2.4, §5.2.2)."""

import numpy as np
import pytest

from repro.core.roadnet import make_road_network
from repro.core.tracking import Detection, TLBFS, TLBase, TLProbabilistic, TLWBFS


@pytest.fixture(scope="module")
def road():
    return make_road_network(num_vertices=300, target_edges=840, seed=3)


@pytest.fixture(scope="module")
def cameras(road):
    return {cam: cam for cam in range(road.num_vertices)}  # camera per vertex


def test_road_network_stats():
    net = make_road_network(num_vertices=1000, target_edges=2817, seed=0)
    assert net.num_vertices == 1000
    assert abs(net.num_edges - 2817) <= 60  # paper: 2817 edges
    assert abs(net.mean_edge_length - 84.5) < 1.0  # rescaled to match

    # connected: weighted ball with infinite radius reaches everything
    ball = net.weighted_ball(0, float("inf"))
    assert len(ball) == net.num_vertices


def test_spotlight_contracts_on_positive(road, cameras):
    tl = TLWBFS(road, cameras, entity_speed=4.0)
    active = tl.update([Detection(camera_id=7, positive=True, timestamp=10.0)], now=10.0)
    assert active == {7}
    assert tl.last_seen_camera == 7


def test_spotlight_expands_while_lost(road, cameras):
    tl = TLWBFS(road, cameras, entity_speed=4.0)
    tl.update([Detection(camera_id=7, positive=True, timestamp=10.0)], now=10.0)
    a1 = tl.update([], now=15.0)   # radius 20 m
    a2 = tl.update([], now=40.0)   # radius 120 m
    assert len(a2) >= len(a1) >= 1
    assert 7 in a1


def test_wbfs_tighter_than_bfs(road, cameras):
    """The paper's §5.2.2 claim: WBFS (true lengths) activates fewer cameras
    than BFS (fixed length) for the same blind-spot duration, because hop
    counting rounds every edge up to the fixed length."""
    es, fixed = 4.0, 84.5
    sizes_bfs, sizes_wbfs = [], []
    for start in [5, 50, 150]:
        bfs = TLBFS(road, cameras, entity_speed=es, fixed_edge_length_m=fixed)
        wbfs = TLWBFS(road, cameras, entity_speed=es)
        for tl in (bfs, wbfs):
            tl.update([Detection(camera_id=start, positive=True, timestamp=0.0)], now=0.0)
        for t in (30.0, 60.0, 90.0):
            sizes_bfs.append(len(bfs.update([], now=t)))
            sizes_wbfs.append(len(wbfs.update([], now=t)))
    assert np.mean(sizes_wbfs) <= np.mean(sizes_bfs) * 1.2
    assert max(sizes_wbfs) <= max(sizes_bfs) * 1.5


def test_tl_base_keeps_everything_active(road, cameras):
    tl = TLBase(road, cameras)
    active = tl.update([Detection(camera_id=3, positive=True, timestamp=1.0)], now=1.0)
    assert active == set(cameras)


def test_probabilistic_subset_of_reachable(road, cameras):
    es = 4.0
    wbfs = TLWBFS(road, cameras, entity_speed=es)
    prob = TLProbabilistic(road, cameras, entity_speed=es, coverage=0.8)
    for tl in (wbfs, prob):
        tl.update([Detection(camera_id=10, positive=True, timestamp=0.0)], now=0.0)
    full = wbfs.update([], now=60.0)
    subset = prob.update([], now=60.0)
    assert subset.issubset(full)
    assert len(subset) >= 1


def test_never_seen_searches_everywhere(road, cameras):
    tl = TLWBFS(road, cameras, entity_speed=4.0)
    tl.last_seen_camera = None
    tl.last_seen_time = None
    assert tl.update([], now=5.0) == set(cameras)
