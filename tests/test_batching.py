"""Batching strategies (§4.4) + clock-skew resilience properties (§4.6.2)."""

import math

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.batching import (
    DynamicBatcher,
    NOBBatcher,
    PendingEvent,
    StaticBatcher,
    build_nob_table,
)
from repro.core.events import Event, EventHeader


def xi(b):
    return 0.05 + 0.01 * b


def pe(eid, arrival, deadline):
    ev = Event(header=EventHeader(event_id=eid, source_arrival=arrival), key=eid)
    return PendingEvent(event=ev, arrival=arrival, deadline=deadline)


class TestDynamicBatcher:
    def test_accumulates_until_deadline(self):
        b = DynamicBatcher(xi, m_max=25)
        # deadline far away: events accumulate
        assert b.offer(pe(0, 0.0, 10.0), 0.0) is None
        assert b.offer(pe(1, 0.1, 10.0), 0.1) is None
        assert b.current_size == 2

    def test_submits_when_event_cannot_join(self):
        b = DynamicBatcher(xi, m_max=25)
        b.offer(pe(0, 0.0, 0.2), 0.0)
        # t + xi(2) = 1.0 + 0.07 > min(0.2, inf) -> flush previous batch
        out = b.offer(pe(1, 1.0, 99.0), 1.0)
        assert out is not None and len(out) == 1 and out[0].event.event_id == 0
        assert b.current_size == 1

    def test_m_max_flushes(self):
        b = DynamicBatcher(xi, m_max=3)
        b.offer(pe(0, 0.0, 100.0), 0.0)
        b.offer(pe(1, 0.0, 100.0), 0.0)
        out = b.offer(pe(2, 0.0, 100.0), 0.0)
        assert out is not None and len(out) == 3

    def test_auto_submit_time_is_deadline_minus_exec(self):
        b = DynamicBatcher(xi, m_max=25)
        b.offer(pe(0, 0.0, 5.0), 0.0)
        b.offer(pe(1, 0.0, 4.0), 0.0)  # batch deadline = min = 4.0
        assert b.next_due_time() == pytest.approx(4.0 - xi(2))
        assert b.flush_if_due(3.0) is None
        out = b.flush_if_due(4.0 - xi(2) + 1e-9)
        assert out is not None and len(out) == 2


class TestStaticBatcher:
    def test_fixed_size(self):
        b = StaticBatcher(xi, batch_size=3)
        assert b.offer(pe(0, 0.0, 1.0), 0.0) is None
        assert b.offer(pe(1, 0.0, 1.0), 0.0) is None
        out = b.offer(pe(2, 0.0, 1.0), 0.0)
        assert out is not None and len(out) == 3

    def test_streaming_b1(self):
        b = StaticBatcher(xi, batch_size=1)
        out = b.offer(pe(0, 0.0, 1.0), 0.0)
        assert out is not None and len(out) == 1

    def test_never_auto_submits(self):
        b = StaticBatcher(xi, batch_size=5)
        b.offer(pe(0, 0.0, 1.0), 0.0)
        assert math.isinf(b.next_due_time())


class TestNOB:
    def test_table_monotone(self):
        table = build_nob_table(xi, m_max=25)
        sizes = [b for _, b in table]
        assert all(b2 >= b1 for b1, b2 in zip(sizes, sizes[1:])), "batch grows with rate"

    def test_picks_small_batches_at_low_rate(self):
        b = NOBBatcher(xi, m_max=25)
        out = None
        for i in range(3):
            out = b.offer(pe(i, i * 1.0, 99.0), i * 1.0)  # 1 event/sec
            if out:
                break
        assert out is not None, "low rate => small batch => quick submit"


# ----------------------------------------------------------------------- #
# Clock-skew resilience (§4.6.2): adding a constant skew sigma to the     #
# local clock shifts arrivals, now, and (learned) deadlines equally, so    #
# the admit decision is unchanged.                                         #
# ----------------------------------------------------------------------- #
@settings(max_examples=200, deadline=None)
@given(
    sigma=st.floats(-50, 50, allow_nan=False),
    arrivals=st.lists(st.floats(0, 10), min_size=2, max_size=8),
    beta=st.floats(0.1, 5.0),
)
def test_dynamic_batcher_skew_invariance(sigma, arrivals, beta):
    arrivals = sorted(arrivals)

    def run(skew: float):
        b = DynamicBatcher(xi, m_max=25)
        decisions = []
        for i, a in enumerate(arrivals):
            # deadline = a_1 + beta measured on the skewed clock: both the
            # event deadline and 'now' carry the same +skew.
            out = b.offer(pe(i, a + skew, a + skew + beta), a + skew)
            decisions.append(0 if out is None else len(out))
        return decisions

    assert run(0.0) == run(sigma)


@settings(max_examples=100, deadline=None)
@given(
    deadlines=st.lists(st.floats(1.0, 20.0), min_size=1, max_size=10),
)
def test_batch_deadline_is_min_of_event_deadlines(deadlines):
    b = DynamicBatcher(xi, m_max=100)
    for i, d in enumerate(deadlines):
        b.offer(pe(i, 0.0, d), 0.0)
    if b.current_size == len(deadlines):  # no intermediate flush happened
        assert b.next_due_time() == pytest.approx(
            min(deadlines) - xi(len(deadlines))
        )
