"""Batching strategies (§4.4).

The hypothesis-based clock-skew resilience properties live in
``test_batching_props.py`` (skipped when the optional ``hypothesis`` test
dependency is missing; see pyproject.toml ``[project.optional-dependencies]``).
"""

import math

import pytest

from repro.core.batching import (
    DynamicBatcher,
    NOBBatcher,
    PendingEvent,
    StaticBatcher,
    build_nob_table,
)
from repro.core.events import Event, EventHeader


def xi(b):
    return 0.05 + 0.01 * b


def pe(eid, arrival, deadline):
    ev = Event(header=EventHeader(event_id=eid, source_arrival=arrival), key=eid)
    return PendingEvent(event=ev, arrival=arrival, deadline=deadline)


class TestDynamicBatcher:
    def test_accumulates_until_deadline(self):
        b = DynamicBatcher(xi, m_max=25)
        # deadline far away: events accumulate
        assert b.offer(pe(0, 0.0, 10.0), 0.0) is None
        assert b.offer(pe(1, 0.1, 10.0), 0.1) is None
        assert b.current_size == 2

    def test_submits_when_event_cannot_join(self):
        b = DynamicBatcher(xi, m_max=25)
        b.offer(pe(0, 0.0, 0.2), 0.0)
        # t + xi(2) = 1.0 + 0.07 > min(0.2, inf) -> flush previous batch
        out = b.offer(pe(1, 1.0, 99.0), 1.0)
        assert out is not None and len(out) == 1 and out[0].event.event_id == 0
        assert b.current_size == 1

    def test_m_max_flushes(self):
        b = DynamicBatcher(xi, m_max=3)
        b.offer(pe(0, 0.0, 100.0), 0.0)
        b.offer(pe(1, 0.0, 100.0), 0.0)
        out = b.offer(pe(2, 0.0, 100.0), 0.0)
        assert out is not None and len(out) == 3

    def test_auto_submit_time_is_deadline_minus_exec(self):
        b = DynamicBatcher(xi, m_max=25)
        b.offer(pe(0, 0.0, 5.0), 0.0)
        b.offer(pe(1, 0.0, 4.0), 0.0)  # batch deadline = min = 4.0
        assert b.next_due_time() == pytest.approx(4.0 - xi(2))
        assert b.flush_if_due(3.0) is None
        out = b.flush_if_due(4.0 - xi(2) + 1e-9)
        assert out is not None and len(out) == 2


class TestStaticBatcher:
    def test_fixed_size(self):
        b = StaticBatcher(xi, batch_size=3)
        assert b.offer(pe(0, 0.0, 1.0), 0.0) is None
        assert b.offer(pe(1, 0.0, 1.0), 0.0) is None
        out = b.offer(pe(2, 0.0, 1.0), 0.0)
        assert out is not None and len(out) == 3

    def test_streaming_b1(self):
        b = StaticBatcher(xi, batch_size=1)
        out = b.offer(pe(0, 0.0, 1.0), 0.0)
        assert out is not None and len(out) == 1

    def test_never_auto_submits(self):
        b = StaticBatcher(xi, batch_size=5)
        b.offer(pe(0, 0.0, 1.0), 0.0)
        assert math.isinf(b.next_due_time())


class TestNOB:
    def test_table_monotone(self):
        table = build_nob_table(xi, m_max=25)
        sizes = [b for _, b in table]
        assert all(b2 >= b1 for b1, b2 in zip(sizes, sizes[1:])), "batch grows with rate"

    def test_picks_small_batches_at_low_rate(self):
        b = NOBBatcher(xi, m_max=25)
        out = None
        for i in range(3):
            out = b.offer(pe(i, i * 1.0, 99.0), i * 1.0)  # 1 event/sec
            if out:
                break
        assert out is not None, "low rate => small batch => quick submit"
