"""Property tests for the completion-budget protocol (paper §4.5, §4.6.2).

Pins the four contracts the dynamism plane leans on:

* a reject signal never *raises* an initialized budget;
* an accept signal never *lowers* an initialized budget;
* out-of-order delivery of a set of same-type signals converges to the same
  final budget (the min/max against ``beta_old`` makes the update a lattice
  operation over the candidates, so permutation-invariant);
* a uniform clock skew ``sigma`` applied to every timestamp cancels: the
  protocol only ever consumes durations (§4.6.2).

Requires the optional ``hypothesis`` test dependency (declared in
pyproject.toml under ``[project.optional-dependencies] test``); the module
is skipped cleanly when it is not installed.
"""

import math

import pytest

hypothesis = pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st

from repro.core.budget import TaskBudget
from repro.core.events import AcceptSignal, EventRecord, RejectSignal


def xi(b):
    return 0.05 + 0.01 * b


def make_budget(m_max=25):
    return TaskBudget("T", xi, m_max=m_max)


records = st.builds(
    EventRecord,
    departure=st.floats(0.01, 30.0),
    queuing=st.floats(0.0, 5.0),
    batch_size=st.integers(1, 25),
    xi=st.floats(0.01, 1.0),
)

rejects = st.builds(
    RejectSignal,
    event_id=st.just(0),  # rebound to the record id by the tests
    epsilon=st.floats(0.0, 10.0),
    q_bar=st.floats(0.0, 10.0),
)

accepts = st.builds(
    AcceptSignal,
    event_id=st.just(0),
    epsilon=st.floats(0.0, 10.0),
    xi_bar=st.floats(0.0, 10.0),
)


# --------------------------------------------------------------------- #
# Monotonicity                                                           #
# --------------------------------------------------------------------- #
@settings(max_examples=200, deadline=None)
@given(rec=records, first=rejects, later=st.lists(rejects, min_size=1, max_size=6))
def test_reject_never_raises_budget(rec, first, later):
    tb = make_budget()
    tb.record(0, rec)
    tb.on_reject(first)
    beta = tb.budget()
    assert not math.isinf(beta)
    for sig in later:
        tb.on_reject(sig)
        assert tb.budget() <= beta
        beta = tb.budget()


@settings(max_examples=200, deadline=None)
@given(rec=records, first=accepts, later=st.lists(accepts, min_size=1, max_size=6))
def test_accept_never_lowers_budget(rec, first, later):
    tb = make_budget()
    tb.record(0, rec)
    tb.on_accept(first)
    beta = tb.budget()
    assert not math.isinf(beta)
    for sig in later:
        tb.on_accept(sig)
        assert tb.budget() >= beta
        beta = tb.budget()


# --------------------------------------------------------------------- #
# Out-of-order delivery converges (§4.5: min/max against beta_old)       #
# --------------------------------------------------------------------- #
@settings(max_examples=150, deadline=None)
@given(
    pairs=st.lists(st.tuples(records, rejects), min_size=2, max_size=6),
    seed=st.integers(0, 2**16),
)
def test_out_of_order_rejects_converge(pairs, seed):
    """Any delivery order of the same reject set yields the same budget."""
    import random

    def final(order):
        tb = make_budget()
        for i, (rec, _) in enumerate(pairs):
            tb.record(i, rec)
        for i in order:
            rec, sig = pairs[i]
            tb.on_reject(RejectSignal(i, sig.epsilon, sig.q_bar))
        return tb.budget()

    order = list(range(len(pairs)))
    expected = final(order)
    random.Random(seed).shuffle(order)
    assert final(order) == expected


@settings(max_examples=150, deadline=None)
@given(
    pairs=st.lists(st.tuples(records, accepts), min_size=2, max_size=6),
    seed=st.integers(0, 2**16),
)
def test_out_of_order_accepts_converge(pairs, seed):
    import random

    def final(order):
        tb = make_budget()
        for i, (rec, _) in enumerate(pairs):
            tb.record(i, rec)
        for i in order:
            rec, sig = pairs[i]
            tb.on_accept(AcceptSignal(i, sig.epsilon, sig.xi_bar))
        return tb.budget()

    order = list(range(len(pairs)))
    expected = final(order)
    random.Random(seed).shuffle(order)
    assert final(order) == expected


# --------------------------------------------------------------------- #
# Clock-skew cancellation (§4.6.2)                                       #
# --------------------------------------------------------------------- #
@settings(max_examples=150, deadline=None)
@given(
    sigma=st.floats(-1e4, 1e4, allow_nan=False),
    events=st.lists(
        st.tuples(
            st.floats(0.0, 100.0),   # source arrival a_1 (absolute)
            st.floats(0.0, 5.0),     # upstream time u
            st.floats(0.0, 5.0),     # queuing q
            st.integers(1, 25),      # batch size m
            st.floats(0.0, 10.0),    # signal epsilon
            st.booleans(),           # accept?
        ),
        min_size=1,
        max_size=8,
    ),
)
def test_uniform_clock_skew_cancels(sigma, events):
    """Records and signals are built from *absolute* timestamps exactly the
    way a task computes them (u = arrival - source_arrival, d = u + q + xi);
    shifting every clock by the same sigma leaves all durations — and hence
    every budget trajectory — bit-identical."""

    def run(skew):
        tb = make_budget()
        for i, (a1, u, q, m, eps, is_accept) in enumerate(events):
            a1s = a1 + skew          # source clock reading
            arrival = a1s + u        # this task's (skewed) clock reading
            exec_end = arrival + q + xi(m)
            rec = EventRecord(
                departure=exec_end - a1s, queuing=q, batch_size=m, xi=xi(m)
            )
            tb.record(i, rec)
            if is_accept:
                tb.on_accept(AcceptSignal(i, eps, xi_bar=xi(m)))
            else:
                tb.on_reject(RejectSignal(i, eps, q_bar=q))
        return tb.budget()

    # Equality up to float round-off: the *protocol* cancels sigma exactly
    # (only durations are consumed), but building absolute timestamps first
    # costs an ulp here and there at extreme sigma.
    assert run(sigma) == pytest.approx(run(0.0), rel=1e-6, abs=1e-9)
