"""Pipeline invariants under drops (satellite of the dynamism-plane PR):

* no event is ever executed (as a normal event) after being dropped;
* every probe emitted at a drop point traverses the full path to the sink;
* the telemetry trace's cumulative drop counters reconcile *exactly* with
  the ``ScenarioResult`` totals, per task and per drop point, across the
  base/bfs/wbfs/prob presets.
"""

import itertools

import pytest

from repro.core.pipeline import Task
from repro.sim import DynamismSpec, ScenarioConfig, TrackingScenario


def _overloaded_cfg(tl, **kw):
    """Constrained deployment (cf. Fig. 11) so drops actually happen."""
    base = dict(
        num_cameras=200 if tl == "base" else 400,
        duration_s=90.0,
        seed=0,
        tl=tl,
        tl_peak_speed=7.0,
        num_va=3,
        num_cr=3,
        batching="dynamic",
        m_max=25,
        drops_enabled=True,
    )
    base.update(kw)
    return ScenarioConfig(**base)


PRESETS = ["base", "bfs", "wbfs", "prob"]


@pytest.mark.parametrize("tl", PRESETS)
def test_no_execution_after_drop_and_probes_reach_sink(tl, monkeypatch):
    seq = itertools.count()
    dropped_at = {}   # event_id -> seq of its drop
    violations = []

    orig_drop = Task._on_drop
    orig_finish = Task._finish_batch

    def logging_drop(self, ev, epsilon, downstream="", point=0):
        dropped_at[ev.header.event_id] = next(seq)
        return orig_drop(self, ev, epsilon, downstream=downstream, point=point)

    def logging_finish(self, batch, exec_start, exec_dur):
        s = next(seq)
        for pe in batch:
            h = pe.event.header
            if not h.is_probe and dropped_at.get(h.event_id, s + 1) < s:
                violations.append((self.name, h.event_id))
        return orig_finish(self, batch, exec_start, exec_dur)

    monkeypatch.setattr(Task, "_on_drop", logging_drop)
    monkeypatch.setattr(Task, "_finish_batch", logging_finish)

    sc = TrackingScenario(_overloaded_cfg(tl))
    res = sc.run()
    assert res.dropped > 0, "overload preset must actually drop"
    assert not violations, f"events executed after being dropped: {violations[:5]}"

    # Every emitted probe completed the path to the sink (§4.5.2: probes
    # are un-droppable, so after the drain none may be missing).
    emitted = sum(t.stats.probes for t in sc.compiled.all_tasks())
    assert emitted > 0, "probe machinery never engaged"
    assert sc.sink.probes_seen == emitted


@pytest.mark.parametrize("tl", PRESETS)
def test_telemetry_drop_counts_reconcile_with_result(tl):
    """Final cumulative dp1+dp2+dp3 per task in the trace == the result's
    drops_by_task, and their sum == ScenarioResult.dropped."""
    cfg = _overloaded_cfg(tl, dynamism=DynamismSpec())  # observe-only spec
    sc = TrackingScenario(cfg)
    res = sc.run()
    trace = res.trace
    assert res.dropped > 0

    traced = {}
    for name in trace.series:
        if name in ("UV", "FC*"):
            continue
        total = trace.dropped_total(name)
        if total:
            traced[name] = total
    # FC drops (if any) are traced in aggregate.
    fc_traced = trace.dropped_total("FC*")
    fc_result = sum(v for k, v in res.drops_by_task.items() if k.startswith("FC"))
    assert fc_traced == fc_result
    va_cr_result = {
        k: v for k, v in res.drops_by_task.items() if not k.startswith("FC")
    }
    assert traced == va_cr_result
    assert sum(traced.values()) + fc_traced == res.dropped
    # Per-drop-point split is internally consistent too: each sampled
    # cumulative column ends at the task's stats counter.
    for t in sc.compiled.va_tasks + sc.compiled.cr_tasks:
        row = trace.series[t.name]
        assert row["dp1"][-1] == t.stats.dropped_dp1
        assert row["dp2"][-1] == t.stats.dropped_dp2
        assert row["dp3"][-1] == t.stats.dropped_dp3
