"""Partitioning rules: divisibility safety + a real small-mesh lower/compile
(8 emulated CPU devices in a subprocess so jax's device count is fresh)."""

import contextlib
import subprocess
import sys
import textwrap
import warnings

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.config import get_config
from repro.distributed.partitioning import MeshRules, constrain, default_rules, param_specs
from repro.models import init_params, reduced_config


def test_constrain_is_noop_without_rules():
    x = jnp.ones((4, 4))
    y = constrain(x, ("batch", None))
    assert y is x


def test_resolve_drops_non_divisible_axes():
    mesh = jax.make_mesh((1,), ("model",))
    rules = MeshRules(mesh=mesh, rules={"model": "model"})
    # 1-wide axis always divides
    assert rules.resolve(("model",), (7,)) == P("model")

    class FakeMesh:
        shape = {"model": 16}
        axis_names = ("model",)

    rules = MeshRules(mesh=FakeMesh(), rules={"model": "model"})
    with pytest.warns(UserWarning, match="sharding dropped"):
        assert rules.resolve(("model",), (25,)) == P(None)  # 25 heads: replicated
    assert rules.resolve(("model",), (32,)) == P("model")


@contextlib.contextmanager
def warnings_none():
    """Assert the block emits no 'sharding dropped' warnings."""
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        yield
    assert not [w for w in rec if "sharding dropped" in str(w.message)]


def test_non_divisible_drop_is_reported_not_hidden():
    """The silent-sharding bug: a 60-expert stack placed expert-parallel on a
    16-wide axis used to replicate quietly (16x the expected memory).  The
    drop must now bump ``sharding_drops`` and warn once, naming the param
    path and the mesh axis."""
    import jax.numpy as _jnp

    class FakeMesh:
        shape = {"model": 16}
        axis_names = ("model",)

    rules = MeshRules(mesh=FakeMesh(), rules={"model": "model", "expert": "model"})
    params = {"experts": {"w_gate": jax.ShapeDtypeStruct((60, 8, 32), _jnp.float32)}}
    with pytest.warns(UserWarning) as rec:
        specs = param_specs(params, rules)
    # (60, 8, 32) wanted ("expert", None, None): E=60 does not divide 16.
    assert specs["experts"]["w_gate"] == P(None, None, None)
    assert rules.sharding_drops == 1
    assert rules.dropped == [("experts/w_gate", "model", 60)]
    msgs = [str(w.message) for w in rec if "sharding dropped" in str(w.message)]
    assert len(msgs) == 1
    assert "experts/w_gate" in msgs[0] and "'model'" in msgs[0] and "60" in msgs[0]
    # Second resolve of the same (path, axis): counted again, warned once.
    with warnings_none():
        param_specs(params, rules)
    assert rules.sharding_drops == 2


@pytest.mark.parametrize("arch", ["qwen3-8b", "deepseek-v2-lite-16b", "mamba2-1.3b", "hymba-1.5b"])
def test_param_specs_cover_all_leaves(arch):
    class FakeMesh:
        shape = {"data": 16, "model": 16}
        axis_names = ("data", "model")

    cfg = get_config(arch)
    rules = MeshRules(
        mesh=FakeMesh(),
        rules={"batch": ("data",), "model": "model", "fsdp": "data", "vocab": "model"},
    )
    params = jax.eval_shape(
        lambda k: init_params(k, cfg, dtype=jnp.bfloat16), jax.random.PRNGKey(0)
    )
    specs = param_specs(params, rules)
    leaves_p = jax.tree.leaves(params)
    leaves_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(leaves_p) == len(leaves_s)
    # every spec's sharded dims divide the leaf dims
    for leaf, spec in zip(leaves_p, leaves_s):
        for dim, axis in zip(leaf.shape, tuple(spec) + (None,) * (len(leaf.shape) - len(spec))):
            if axis is None:
                continue
            size = 16 if isinstance(axis, str) else 16 ** len(axis)
            assert dim % size == 0, (arch, leaf.shape, spec)


SMALL_MESH_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.config import get_config
    from repro.distributed.partitioning import default_rules, mesh_rules, param_specs
    from repro.models import init_params, reduced_config
    from repro.training import TrainConfig, init_adamw, make_train_step

    cfg = reduced_config(get_config("llama3.2-1b"))
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    rules = default_rules(mesh)
    with mesh, mesh_rules(rules):
        params = jax.eval_shape(lambda k: init_params(k, cfg), jax.random.PRNGKey(0))
        p_shard = jax.tree.map(lambda s: NamedSharding(mesh, s),
                               param_specs(params, rules),
                               is_leaf=lambda x: isinstance(x, P))
        opt = jax.eval_shape(init_adamw, params)
        o_shard = type(opt)(step=NamedSharding(mesh, P()), mu=p_shard, nu=p_shard)
        batch = {
            "tokens": jax.ShapeDtypeStruct((8, 64), jnp.int32),
            "labels": jax.ShapeDtypeStruct((8, 64), jnp.int32),
        }
        b_shard = {k: NamedSharding(mesh, P(("data",), None)) for k in batch}
        step = make_train_step(cfg, TrainConfig())
        compiled = jax.jit(
            step, in_shardings=(p_shard, o_shard, b_shard),
            out_shardings=(p_shard, o_shard, None),
        ).lower(params, opt, batch).compile()
        text = compiled.as_text()
        assert "all-reduce" in text or "reduce-scatter" in text, "expected collectives"
        print("SMALL_MESH_OK")
    """
)


def test_small_mesh_train_step_compiles_with_collectives():
    res = subprocess.run(
        [sys.executable, "-c", SMALL_MESH_SCRIPT],
        capture_output=True,
        text=True,
        env={**__import__("os").environ, "PYTHONPATH": "src"},
        timeout=420,
    )
    assert "SMALL_MESH_OK" in res.stdout, res.stdout + res.stderr
