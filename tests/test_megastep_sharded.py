"""Sharded mega-step gate: bit-exactness across mesh widths.

The sharded engine (`repro.kernels.megastep.sharded`) is only allowed to
exist because its result is **bit-identical** to the single-shard scan and
therefore to the interpreted pipeline — every test here compares the full
observable state (global + per-query summaries, raw latency lists, active
timelines, requested/applied mirrors) across 1/2/4/8-way camera meshes on
the 8 emulated host devices the suite-wide conftest forces, and asserts
the engine + shard count actually used so a silent single-shard fallback
can't masquerade as mesh coverage.

Cross-device-count invariance (seed-0 per-query summaries and journal
digests identical under 1, 2 and 8 *visible* host devices) runs in
subprocesses, because the forced device count is fixed at jax backend
init.
"""

import copy
import json
import os
import subprocess
import sys
import textwrap

import pytest

from repro.query import MultiQueryScenario, QuerySpec
from repro.sim import ScenarioConfig

from test_megastep import BASE, MIXED_SPECS, _deep

jax = pytest.importorskip("jax")

SHARDED = dict(BASE, duration_s=60.0)


def _mesh(n):
    from repro.distributed import camera_mesh

    return camera_mesh(jax.devices()[:n])


def _run(cfg, specs, engine, **mq_kw):
    c = copy.deepcopy(cfg)
    c.engine = engine
    scn = MultiQueryScenario(c, copy.deepcopy(specs), **mq_kw)
    res = scn.run()
    return _deep(res), scn


@pytest.mark.parametrize("shards", [2, 4, 8])
def test_sharded_bit_identical_to_interpreted_and_single_shard(shards):
    if len(jax.devices()) < shards:
        pytest.skip(f"needs {shards} devices")
    cfg = ScenarioConfig(**SHARDED)
    ref, ref_scn = _run(cfg, MIXED_SPECS, "interpreted")
    assert ref_scn.engine_used == "interpreted"
    solo, solo_scn = _run(cfg, MIXED_SPECS, "megastep")
    assert solo_scn.engine_used == "megastep-device"
    got, scn = _run(cfg, MIXED_SPECS, "megastep", mesh=_mesh(shards))
    assert scn.engine_used == "megastep-device"
    assert scn.shard_fallback_reason == ""
    assert scn.shards_used == shards
    assert scn.collective_bytes_per_tick > 0
    assert got == ref
    assert got == solo


def test_single_device_mesh_falls_back_bit_exactly():
    """One visible device: the unsharded scan IS the single-shard path —
    the mesh handle must not change the result, and the fallback must be
    recorded, not silent."""
    cfg = ScenarioConfig(**SHARDED)
    solo, _ = _run(cfg, MIXED_SPECS, "megastep")
    got, scn = _run(cfg, MIXED_SPECS, "megastep", mesh=_mesh(1))
    assert scn.engine_used == "megastep-device"
    assert scn.shards_used == 1
    assert scn.shard_fallback_reason == "single-device"
    assert got == solo


def test_mesh_without_cameras_axis_is_recorded():
    from repro.distributed import MeshRules
    from jax.sharding import Mesh
    import numpy as np

    rules = MeshRules(
        mesh=Mesh(np.array(jax.devices()[:2]), ("model",)), rules={}
    )
    cfg = ScenarioConfig(**SHARDED)
    solo, _ = _run(cfg, MIXED_SPECS, "megastep")
    got, scn = _run(cfg, MIXED_SPECS, "megastep", mesh=rules)
    assert scn.shard_fallback_reason == "no-cameras-axis"
    assert got == solo


def test_drops_on_keeps_des_backend_with_mesh():
    """Drops on -> the event DAG backend; the mesh handle must neither
    break eligibility nor perturb the result (acceptance: drops off AND
    on)."""
    cfg = ScenarioConfig(**{**SHARDED, "drops_enabled": True})
    specs = [QuerySpec(tl="bfs"), QuerySpec(tl="wbfs")]
    ref, _ = _run(cfg, specs, "interpreted")
    got, scn = _run(cfg, specs, "megastep", mesh=_mesh(4))
    assert scn.engine_used == "megastep-des"
    assert scn.shard_fallback_reason == "mesh-unused"
    assert got == ref


def test_budget_counters_all_reduced_match_recount():
    """The per-query sourced/positives books handed over by the on-device
    psum must equal the interpreted registry's books exactly."""
    cfg = ScenarioConfig(**SHARDED)
    ref, ref_scn = _run(cfg, MIXED_SPECS, "interpreted")
    got, scn = _run(cfg, MIXED_SPECS, "megastep", mesh=_mesh(8))
    for qid in ref["per"]:
        assert got["per"][qid]["sourced"] == ref["per"][qid]["sourced"]


def test_budget_counters_survive_multiple_scan_chunks():
    """Regression: at fps=1 a >256 s run spans several K=256-tick scan
    chunks.  The budget counters are replicated carries, so the per-chunk
    all-reduce must sum only each chunk's *local delta* — psum-ing the
    running total re-counts every prior chunk once per shard and inflates
    ``sourced``/``positives`` by ~D× (caught at the benchmark's full
    scale; the 60 s gates above are single-chunk and never see it)."""
    cfg = ScenarioConfig(**dict(SHARDED, duration_s=300.0))
    solo, solo_scn = _run(cfg, MIXED_SPECS, "megastep")
    assert solo_scn.engine_used == "megastep-device"
    got, scn = _run(cfg, MIXED_SPECS, "megastep", mesh=_mesh(4))
    assert scn.engine_used == "megastep-device"
    assert scn.shard_fallback_reason == ""
    assert got == solo


# --------------------------------------------------------------------- #
# Cross-device-count invariance (separate processes: the forced host     #
# device count is baked in at jax backend init)                          #
# --------------------------------------------------------------------- #
DIGEST_SCRIPT = textwrap.dedent("""
    import json, os, sys
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=" + sys.argv[1]
    )
    import jax
    from repro.query import MultiQueryScenario, QuerySpec
    from repro.serving import Journal
    from repro.sim import ScenarioConfig

    cfg = ScenarioConfig(num_cameras=60, duration_s=40.0, seed=0, tl="bfs",
                         batching="dynamic", m_max=25, engine="megastep")
    specs = [QuerySpec(tl="wbfs"), QuerySpec(tl="bfs", tl_peak_speed=6.0)]

    scn = MultiQueryScenario(cfg, specs)
    if len(jax.devices()) > 1:
        from repro.distributed import camera_mesh
        scn = MultiQueryScenario(cfg, specs, mesh=camera_mesh())
    res = scn.run()
    per = {qid: res.per_query_summary(qid) for qid in sorted(res.per_query)}

    jcfg = ScenarioConfig(num_cameras=60, duration_s=40.0, seed=0, tl="bfs",
                          batching="dynamic", m_max=25)
    jscn = MultiQueryScenario(jcfg, specs, journal=Journal(10.0))
    jscn.run()

    print(json.dumps({
        "devices": len(jax.devices()),
        "engine": scn.engine_used,
        "shards": scn.shards_used,
        "per": per,
        "journal": jscn.journal.digest(),
    }, sort_keys=True))
""")


def test_seed0_summaries_and_journal_digest_device_count_invariant():
    outs = {}
    for n in (1, 2, 8):
        env = {**os.environ, "PYTHONPATH": "src"}
        env.pop("XLA_FLAGS", None)
        r = subprocess.run(
            [sys.executable, "-c", DIGEST_SCRIPT, str(n)],
            capture_output=True, text=True, env=env,
            cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        )
        assert r.returncode == 0, r.stderr[-2000:]
        outs[n] = json.loads(r.stdout.strip().splitlines()[-1])
    assert outs[1]["devices"] == 1 and outs[1]["shards"] == 1
    assert outs[2]["devices"] == 2 and outs[2]["shards"] == 2
    assert outs[8]["devices"] == 8 and outs[8]["shards"] == 8
    for n in (1, 2, 8):
        assert outs[n]["engine"] == "megastep-device"
    # Per-query books and journal digests must not see the device count.
    assert outs[1]["per"] == outs[2]["per"] == outs[8]["per"]
    assert outs[1]["journal"] == outs[2]["journal"] == outs[8]["journal"]


# --------------------------------------------------------------------- #
# Property: shard count never changes the per-query reconciliation       #
# --------------------------------------------------------------------- #
try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:

    @settings(max_examples=8, deadline=None)
    @given(
        shards=st.sampled_from([2, 4, 8]),
        n_queries=st.integers(min_value=1, max_value=4),
        duration=st.sampled_from([20.0, 35.0]),
        tl=st.sampled_from(["bfs", "wbfs"]),
    )
    def test_shard_count_never_changes_reconciliation(
        shards, n_queries, duration, tl
    ):
        """For every query: ``sourced == completed + dropped + orphans``
        behaves identically whatever the shard count — the books balance
        (or carry the same in-flight remainder) on 1 and on D shards."""
        if len(jax.devices()) < shards:
            pytest.skip(f"needs {shards} devices")
        cfg = ScenarioConfig(num_cameras=60, duration_s=duration, seed=0,
                             tl=tl, batching="dynamic", m_max=25)
        specs = [
            QuerySpec(tl=tl, tl_peak_speed=3.0 + (i % 3))
            for i in range(n_queries)
        ]

        def books(mesh):
            kw = {"mesh": mesh} if mesh is not None else {}
            c = copy.deepcopy(cfg)
            c.engine = "megastep"
            scn = MultiQueryScenario(c, copy.deepcopy(specs), **kw)
            res = scn.run()
            assert scn.engine_used == "megastep-device"
            out = {}
            for qid in res.per_query:
                qs = res.registry.get(qid)
                out[qid] = (
                    qs.sourced, qs.completed, qs.dropped,
                    qs.orphan_completed, qs.orphan_dropped, qs.in_flight,
                )
            return out

        solo = books(None)
        sharded = books(_mesh(shards))
        assert sharded == solo
        for qid, (srcd, comp, drop, oc, od, in_flight) in sharded.items():
            assert srcd == comp + drop + oc + od + in_flight
