"""SweepRunner: concurrent grid execution must be bit-identical to
sequential runs, share one world build per key, and fall back to serial."""

import os

import pytest

from repro.sim import ScenarioConfig, SweepRunner, TrackingScenario


def _grid():
    base = dict(num_cameras=1000, duration_s=40.0, seed=0, tl="bfs")
    return [
        ("sb1", ScenarioConfig(**base, batching="static", static_batch=1,
                               tl_peak_speed=4.0)),
        ("db25", ScenarioConfig(**base, batching="dynamic", m_max=25,
                                tl_peak_speed=6.0)),
        ("nob", ScenarioConfig(**base, batching="nob", m_max=25,
                               tl_peak_speed=4.0)),
        ("drops", ScenarioConfig(**base, batching="dynamic", m_max=25,
                                 tl_peak_speed=7.0, num_va=5, num_cr=5,
                                 drops_enabled=True, avoid_drop_positives=True)),
        # Unpicklable config member: the fork path must carry it through the
        # inherited grid, not pickle it.
        ("bwdrop", ScenarioConfig(**base, batching="dynamic", m_max=25,
                                  tl_peak_speed=4.0,
                                  bandwidth_schedule=lambda t: 1.0 if t < 20.0 else 0.03)),
    ]


@pytest.fixture(scope="module")
def sequential_summaries():
    return {name: TrackingScenario(cfg).run().summary() for name, cfg in _grid()}


def test_serial_sweep_bit_identical_to_sequential(sequential_summaries):
    res = SweepRunner(mode="serial").run(_grid())
    assert res.mode == "serial"
    assert [r.name for r in res.records] == [name for name, _ in _grid()]
    for rec in res.records:
        assert rec.summary == sequential_summaries[rec.name], rec.name


@pytest.mark.skipif(not SweepRunner.fork_available(), reason="needs fork")
def test_fork_sweep_bit_identical_to_sequential():
    """Runs in a fresh interpreter: the pytest process has JAX (multithreaded
    XLA) initialized by other test modules, and forking a JAX-initialized
    parent is the documented deadlock hazard the runner itself avoids."""
    import subprocess
    import sys
    import textwrap

    code = textwrap.dedent(
        """
        from repro.sim import SweepRunner, TrackingScenario
        from tests.test_sweep import _grid

        seq = {name: TrackingScenario(cfg).run().summary() for name, cfg in _grid()}
        res = SweepRunner(mode="fork").run(_grid())
        assert res.mode == "fork" and res.workers >= 1
        assert [r.name for r in res.records] == [name for name, _ in _grid()]
        for rec in res.records:
            assert rec.summary == seq[rec.name], rec.name
            assert rec.run_s > 0.0 and rec.build_s > 0.0
        print("FORK_SWEEP_OK")
        """
    )
    env = dict(os.environ)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(root, "src"), root, env.get("PYTHONPATH", "")]
    )
    proc = subprocess.run(
        [sys.executable, "-c", code], env=env, cwd=root,
        capture_output=True, text=True, timeout=300,
    )
    assert proc.returncode == 0, proc.stderr
    assert "FORK_SWEEP_OK" in proc.stdout


def test_sweep_builds_each_world_once(sequential_summaries):
    res = SweepRunner(mode="serial").run(_grid())
    # All five configs share one (num_cameras, seed, horizon) world; it may
    # already be resident from an earlier sweep, but never built twice.
    assert res.worlds_built <= 1
    assert sum(r.world_build_s for r in res.records) == 0.0


def test_cold_serial_rebuilds_per_case(sequential_summaries):
    grid = _grid()[:2]
    res = SweepRunner(mode="serial", share_worlds=False).run(grid)
    assert res.mode == "serial"
    assert res.worlds_built == len(grid)  # one world built per case
    assert res.world_build_s > 0.0
    for rec in res.records:
        assert rec.world_build_s > 0.0  # every case built its own world
        assert rec.summary == sequential_summaries[rec.name]


def test_cold_auto_forces_serial_and_fork_cold_rejected():
    runner = SweepRunner(mode="auto", share_worlds=False)
    res = runner.run(_grid()[:2])
    assert res.mode == "serial"
    with pytest.raises(ValueError):
        SweepRunner(mode="fork", share_worlds=False)


def test_auto_mode_resolution():
    runner = SweepRunner(mode="auto")
    mode, workers = runner._resolve_mode(1)
    assert (mode, workers) == ("serial", 1)
    if SweepRunner.fork_available() and (os.cpu_count() or 1) > 1:
        mode, workers = runner._resolve_mode(4)
        assert mode == "fork" and 2 <= workers <= 4


def test_invalid_mode_rejected():
    with pytest.raises(ValueError):
        SweepRunner(mode="threads")
