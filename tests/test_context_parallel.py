"""Context-parallel decode attention: LSE-combine correctness.

The combine identity is checked single-host (pure math), and the full
shard_map path runs in a subprocess with 8 emulated devices.
"""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.context_parallel import combine_partials, decode_attention_partial
from repro.kernels.decode_attention.ref import decode_attention_ref

KEY = jax.random.PRNGKey(0)


def test_partial_plus_combine_equals_reference():
    """Splitting the cache into local shards and LSE-combining the partials
    must reproduce the monolithic softmax exactly."""
    B, Hq, Hkv, T, D, S = 2, 4, 2, 96, 32, 4
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (B, Hq, D))
    k = jax.random.normal(ks[1], (B, Hkv, T, D))
    v = jax.random.normal(ks[2], (B, Hkv, T, D))
    length = jnp.asarray([70, 33], jnp.int32)
    ref = decode_attention_ref(q, k, v, length)

    T_loc = T // S
    outs, ms, ls = [], [], []
    for i in range(S):
        k_l = k[:, :, i * T_loc : (i + 1) * T_loc]
        v_l = v[:, :, i * T_loc : (i + 1) * T_loc]
        pos = i * T_loc + jnp.arange(T_loc)[None, :]
        valid = pos < length[:, None]
        o, m, l = decode_attention_partial(q, k_l, v_l, valid, scale=D ** -0.5)
        outs.append(o), ms.append(m), ls.append(l)
    got = combine_partials(jnp.stack(outs), jnp.stack(ms), jnp.stack(ls))
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref, np.float32), atol=1e-5)


def test_empty_shards_are_safe():
    """Shards entirely past `length` contribute exp(-inf)=0, not NaN."""
    B, Hq, Hkv, T, D = 1, 2, 2, 32, 16
    q = jax.random.normal(KEY, (B, Hq, D))
    k = jax.random.normal(KEY, (B, Hkv, T, D))
    v = jax.random.normal(KEY, (B, Hkv, T, D))
    length = jnp.asarray([8], jnp.int32)  # second half of cache invalid
    o1, m1, l1 = decode_attention_partial(
        q, k[:, :, :16], v[:, :, :16],
        (jnp.arange(16)[None] < length[:, None]), scale=D ** -0.5,
    )
    o2, m2, l2 = decode_attention_partial(
        q, k[:, :, 16:], v[:, :, 16:],
        (16 + jnp.arange(16)[None] < length[:, None]), scale=D ** -0.5,
    )
    got = combine_partials(jnp.stack([o1, o2]), jnp.stack([m1, m2]), jnp.stack([l1, l2]))
    assert bool(jnp.all(jnp.isfinite(got)))
    ref = decode_attention_ref(q, k, v, length)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref, np.float32), atol=1e-5)


SHARD_MAP_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.distributed.context_parallel import context_parallel_decode_attention
    from repro.kernels.decode_attention.ref import decode_attention_ref

    mesh = jax.make_mesh((8,), ("data",))
    key = jax.random.PRNGKey(0)
    B, Hq, Hkv, T, D = 2, 4, 2, 128, 32
    q = jax.random.normal(key, (B, Hq, D))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, Hkv, T, D))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, Hkv, T, D))
    length = jnp.asarray([100, 47], jnp.int32)
    got = context_parallel_decode_attention(mesh, "data", q, k, v, length)
    ref = decode_attention_ref(q, k, v, length)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref, np.float32), atol=1e-5)
    # the lowered program must NOT all-gather the cache: biggest collective
    # should be the (S,B,Hq,D)-ish stats gather, far below cache size.
    txt = jax.jit(lambda *a: context_parallel_decode_attention(mesh, "data", *a)) \
        .lower(q, k, v, length).compile().as_text()
    import re
    gathers = re.findall(r"all-gather[^=]*", txt)
    print("SHARD_MAP_CP_OK", len(gathers))
    """
)


def test_shard_map_context_parallel_8dev():
    res = subprocess.run(
        [sys.executable, "-c", SHARD_MAP_SCRIPT],
        capture_output=True, text=True,
        env={**os.environ, "PYTHONPATH": "src"},
        timeout=420,
    )
    assert "SHARD_MAP_CP_OK" in res.stdout, res.stdout + res.stderr
