"""WorldBundle sharing: key derivation, in-process + on-disk caches, and
the 10k-camera "second construction is nearly free" acceptance check."""

import math
import time

import numpy as np
import pytest

from repro.sim import (
    ScenarioConfig,
    TrackingScenario,
    WorldKey,
    clear_world_cache,
    get_world,
    world_cache_stats,
)
from repro.sim.world import build_world


def test_world_key_matches_legacy_parameter_derivation():
    # Default: paper's 1000-vertex / 2817-edge network.
    key = WorldKey.from_config(ScenarioConfig(num_cameras=1000, seed=3))
    assert (key.road_vertices, key.road_edges, key.seed) == (1000, 2817, 3)
    # Camera count above the vertex count grows the graph proportionally.
    key = WorldKey.from_config(ScenarioConfig(num_cameras=5000))
    assert (key.road_vertices, key.road_edges) == (5000, int(round(5000 * 2.817)))
    # Explicit road_vertices wins.
    key = WorldKey.from_config(ScenarioConfig(num_cameras=100, road_vertices=400))
    assert (key.road_vertices, key.road_edges) == (400, int(round(400 * 2.817)))
    # The walk horizon follows duration (+60s drain), so it is part of the key.
    a = WorldKey.from_config(ScenarioConfig(duration_s=60.0))
    b = WorldKey.from_config(ScenarioConfig(duration_s=600.0))
    assert a != b and a.walk_horizon_s == 120.0


def test_world_bundle_matches_inline_build():
    """A bundle world is bit-identical to what the scenario constructor
    used to build inline (same RNG seeds, same derived parameters)."""
    cfg = ScenarioConfig(num_cameras=150, road_vertices=200, duration_s=30.0, seed=11)
    bundle = build_world(WorldKey.from_config(cfg))
    assert bundle.road.num_vertices == 200
    assert bundle.cameras.num_cameras == 150
    sc = TrackingScenario(cfg)
    np.testing.assert_array_equal(sc.road.positions, bundle.road.positions)
    assert sc.road.adjacency == bundle.road.adjacency
    assert sc.walk.vertices == bundle.walk.vertices
    assert sc.cameras.camera_vertices == bundle.cameras.camera_vertices


def test_get_world_memoizes_in_process():
    cfg = ScenarioConfig(num_cameras=50, road_vertices=120, duration_s=20.0, seed=21)
    key = WorldKey.from_config(cfg)
    before = world_cache_stats()
    w1 = get_world(key)
    w2 = get_world(key)
    assert w1 is w2
    after = world_cache_stats()
    assert after["memory_hits"] >= before["memory_hits"] + 1
    # Scenario constructions share the same bundle objects.
    s1 = TrackingScenario(cfg)
    s2 = TrackingScenario(cfg)
    assert s1.road is s2.road and s1.walk is s2.walk and s1.cameras is s2.cameras
    assert s1.world is s2.world


def test_config_world_handle_and_mismatch_rejection():
    cfg = ScenarioConfig(num_cameras=40, road_vertices=100, duration_s=20.0, seed=5)
    bundle = get_world(WorldKey.from_config(cfg))
    sc = TrackingScenario(ScenarioConfig(
        num_cameras=40, road_vertices=100, duration_s=20.0, seed=5, world=bundle
    ))
    assert sc.world is bundle and sc.world_build_seconds == 0.0
    with pytest.raises(ValueError):
        TrackingScenario(ScenarioConfig(
            num_cameras=41, road_vertices=100, duration_s=20.0, seed=5, world=bundle
        ))


def test_disk_cache_roundtrip(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_WORLD_CACHE", str(tmp_path))
    cfg = ScenarioConfig(num_cameras=60, road_vertices=150, duration_s=20.0, seed=31)
    key = WorldKey.from_config(cfg)
    fresh = get_world(key)
    summary_fresh = TrackingScenario(cfg).run().summary()
    assert any(p.name.startswith("world_") for p in tmp_path.iterdir())
    # Drop the in-process entry; the next fetch must come from disk and be
    # bit-identical (pickle roundtrips floats exactly).
    clear_world_cache()
    loaded = get_world(key)
    assert loaded is not fresh
    assert world_cache_stats()["disk_hits"] == 1
    np.testing.assert_array_equal(loaded.road.positions, fresh.road.positions)
    assert loaded.road.adjacency == fresh.road.adjacency
    assert loaded.walk.vertices == fresh.walk.vertices
    assert loaded.cameras.camera_vertices == fresh.cameras.camera_vertices
    assert TrackingScenario(cfg).run().summary() == summary_fresh


def test_disk_cache_disabled_by_default(tmp_path, monkeypatch):
    monkeypatch.delenv("REPRO_WORLD_CACHE", raising=False)
    clear_world_cache()
    cfg = ScenarioConfig(num_cameras=30, road_vertices=90, duration_s=20.0, seed=41)
    get_world(WorldKey.from_config(cfg))
    assert world_cache_stats()["disk_writes"] == 0


def test_embed_dim_scenarios_do_not_share_camera_rng():
    """Embedding-enabled camera networks are stateful: each scenario must
    own a fresh one (sharing road + walk), so two runs are identical."""
    cfg = ScenarioConfig(
        num_cameras=50, road_vertices=120, duration_s=20.0, seed=51, embed_dim=8,
        tl="base", batching="static", static_batch=5,
    )
    s1 = TrackingScenario(cfg)
    s2 = TrackingScenario(cfg)
    assert s1.cameras is not s2.cameras
    assert s1.road is s2.road
    assert s1.run().summary() == s2.run().summary()


def test_second_10k_construction_under_ten_percent_of_first():
    """Acceptance: a WorldBundle cache hit makes the second 10k-camera
    scenario construct in <10% of the first's build time.  Warm time is
    best-of-two: a single sample occasionally eats a scheduler hiccup on a
    loaded CI machine and the margin (typically ~1%) is thin only then."""
    cfg = ScenarioConfig(
        num_cameras=10_000, duration_s=10.0, tl="bfs", batching="dynamic",
        m_max=25, seed=9,
    )
    t0 = time.perf_counter()
    first = TrackingScenario(cfg)
    t_first = time.perf_counter() - t0
    assert first.world_build_seconds > 0.0  # cold: this call built the world
    t_second = math.inf
    for _ in range(2):
        t0 = time.perf_counter()
        second = TrackingScenario(cfg)
        t_second = min(t_second, time.perf_counter() - t0)
        assert second.world is first.world
    assert t_second < 0.1 * t_first, (
        f"warm construction {t_second:.3f}s vs cold {t_first:.3f}s"
    )
