"""Mega-step engine gate: bit-exactness against the interpreted pipeline.

``ScenarioConfig.engine = "megastep"`` may lower the per-tick hot loop to
the fused device scan (``repro.kernels.megastep``), the host chain mirror,
or the plan-driven tick driver (drops on) — but it is only allowed to exist
because the result is **bit-identical** to the interpreted
``MultiQueryScenario``.  These tests are that gate: every backend is
compared field-by-field (global + per-query summaries, raw latency lists,
active timelines, batch sizes, drop books, requested/applied control
mirrors) against an interpreted run of the same config, and the engine
actually used is asserted so a silent fallback can't masquerade as
coverage.
"""

import copy
import os

import numpy as np
import pytest

# Full-duration golden replays are the strict gate but dominate the tier-1
# wall (see PERF.md §PR-9); they run under REPRO_RUN_SLOW=1 while a
# shortened-horizon equivalent of each stays in tier-1.
slow = pytest.mark.skipif(
    os.environ.get("REPRO_RUN_SLOW", "") != "1",
    reason="full-duration golden replay; set REPRO_RUN_SLOW=1",
)

from repro.query import MultiQueryScenario, QuerySpec
from repro.sim import ScenarioConfig

# 60 cameras / 10 lanes keeps every drops-off case on one compiled chunk
# shape (Cb=64, Nb=8, T->Kb=128) so the module compiles the scan once.
BASE = dict(
    num_cameras=60, duration_s=120.0, seed=0, tl="bfs",
    batching="dynamic", m_max=25,
)

MIXED_SPECS = [
    QuerySpec(tl="wbfs"),
    QuerySpec(tl="bfs", tl_peak_speed=6.0),
    QuerySpec(tl="base"),
    QuerySpec(tl="wbfs", last_seen_camera=40),
]


def _deep(res):
    """Everything observable about a MultiQueryResult, exactly."""
    out = {
        "global": res.result.summary(),
        "g_lat": res.result.latencies,
        "g_active": res.result.active_timeline,
        "g_batch": res.result.batch_sizes,
        "g_drops": res.result.drops_by_task,
        "states": res.states,
        "per": {},
    }
    for qid, r in res.per_query.items():
        st = res.registry.get(qid)
        out["per"][qid] = {
            "summary": res.per_query_summary(qid),
            "lat": r.latencies,
            "active": r.active_timeline,
            "sourced": st.sourced,
            "requested": sorted(st.requested),
            "applied": sorted(st.applied),
        }
    return out


def _run(cfg, specs, engine, **mq_kw):
    c = copy.deepcopy(cfg)
    c.engine = engine
    scn = MultiQueryScenario(c, copy.deepcopy(specs), **mq_kw)
    res = scn.run()
    return _deep(res), scn.engine_used, scn.engine_fallback_reason


def check_bit_identical(cfg, specs, expect_engine, **mq_kw):
    ref, ref_engine, _ = _run(cfg, specs, "interpreted", **mq_kw)
    assert ref_engine == "interpreted"
    got, engine, reason = _run(cfg, specs, "megastep", **mq_kw)
    assert engine == expect_engine, (engine, reason)
    assert got == ref
    return got


# --------------------------------------------------------------------- #
# Device backend (drops off, finite-parameter table TLs)                  #
# --------------------------------------------------------------------- #
def test_device_mixed_tls_bit_identical():
    """base + bfs + wbfs (default and custom seeds/speeds) in one run."""
    check_bit_identical(ScenarioConfig(**BASE), MIXED_SPECS, "megastep-device")


def test_device_static_batch_one():
    cfg = ScenarioConfig(**{**BASE, "batching": "static", "static_batch": 1})
    specs = [QuerySpec(tl="bfs"), QuerySpec(tl="wbfs", tl_peak_speed=3.0)]
    check_bit_identical(cfg, specs, "megastep-device")


def test_device_single_query():
    check_bit_identical(
        ScenarioConfig(**BASE), [QuerySpec(tl="wbfs")], "megastep-device"
    )


def test_device_multi_lane():
    cfg = ScenarioConfig(**{**BASE, "num_va": 4, "num_cr": 4})
    specs = [QuerySpec(tl="bfs"), QuerySpec(tl="wbfs")]
    check_bit_identical(cfg, specs, "megastep-device")


# --------------------------------------------------------------------- #
# Host backend (object TLs / overload divergence)                         #
# --------------------------------------------------------------------- #
def test_host_fallback_on_overload():
    """A TLBase query holding all 300 cameras active at fps=1 overloads the
    10-lane pipeline: in-flight detections grow past the device ring cap,
    the scan flags divergence, and the run lands on the host mirror —
    still bit-identical."""
    cfg = ScenarioConfig(**{**BASE, "num_cameras": 300, "duration_s": 150.0})
    specs = [
        QuerySpec(tl="wbfs"),
        QuerySpec(tl="bfs", tl_peak_speed=6.0),
        QuerySpec(tl="base"),
        QuerySpec(tl="wbfs", last_seen_camera=120),
    ]
    check_bit_identical(cfg, specs, "megastep-host")


def test_host_probabilistic_tl():
    """TLProbabilistic has no finite (radius, hop) table — the host backend
    drives the real TL objects through the chain mirror."""
    cfg = ScenarioConfig(**{**BASE, "num_cameras": 150, "duration_s": 60.0,
                            "tl": "prob"})
    specs = [QuerySpec(tl="prob"), QuerySpec(tl="wbfs")]
    check_bit_identical(cfg, specs, "megastep-host")


def test_host_kernel_spotlight_mode():
    """Shortened-horizon tier-1 version of the full-duration golden below."""
    cfg = ScenarioConfig(**{**BASE, "tl": "wbfs", "duration_s": 25.0})
    specs = [QuerySpec(tl="wbfs"), QuerySpec(tl="wbfs", tl_peak_speed=3.0)]
    check_bit_identical(cfg, specs, "megastep-host", spotlight_mode="kernel")


@pytest.mark.slow
@slow
def test_host_kernel_spotlight_mode_full_duration():
    cfg = ScenarioConfig(**{**BASE, "tl": "wbfs"})
    specs = [QuerySpec(tl="wbfs"), QuerySpec(tl="wbfs", tl_peak_speed=3.0)]
    check_bit_identical(cfg, specs, "megastep-host", spotlight_mode="kernel")


# --------------------------------------------------------------------- #
# Drops on: plan-driven tick driver over the real event DAG               #
# --------------------------------------------------------------------- #
def test_des_drops_streaming():
    cfg = ScenarioConfig(**{**BASE, "drops_enabled": True})
    specs = [QuerySpec(tl="bfs"), QuerySpec(tl="wbfs")]
    check_bit_identical(cfg, specs, "megastep-des")


def test_des_drops_static_batch():
    cfg = ScenarioConfig(**{**BASE, "drops_enabled": True,
                            "avoid_drop_positives": True,
                            "batching": "static", "static_batch": 10,
                            "duration_s": 90.0})
    specs = [QuerySpec(tl="wbfs"), QuerySpec(tl="base")]
    check_bit_identical(cfg, specs, "megastep-des")


# --------------------------------------------------------------------- #
# Interpreted fallbacks: everything else keeps the reference pipeline     #
# --------------------------------------------------------------------- #
def test_interpreted_fallback_reasons():
    from repro.sim import DynamismSpec

    small = {**BASE, "duration_s": 20.0}

    cfg = ScenarioConfig(**small, dynamism=DynamismSpec())
    _, engine, reason = _run(cfg, [QuerySpec(tl="wbfs")], "megastep")
    assert (engine, reason) == ("interpreted", "dynamism")

    _, engine, reason = _run(
        ScenarioConfig(**small),
        [QuerySpec(tl="wbfs"), QuerySpec(tl="wbfs", submit_at=5.0)],
        "megastep",
    )
    assert (engine, reason) == ("interpreted", "query-lifecycle")


def test_interpreted_fallback_is_bit_identical():
    """The fallback isn't a degraded mode: engine="megastep" on an
    ineligible config must return exactly the interpreted result."""
    from repro.sim import DynamismSpec

    cfg = ScenarioConfig(**{**BASE, "duration_s": 40.0},
                         dynamism=DynamismSpec())
    specs = [QuerySpec(tl="wbfs")]
    ref, _, _ = _run(cfg, specs, "interpreted")
    got, engine, _ = _run(cfg, specs, "megastep")
    assert engine == "interpreted"
    assert got == ref
