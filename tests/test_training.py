"""Training substrate: optimizer math, schedules, loss descent, checkpoints."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_config
from repro.models import init_params, reduced_config
from repro.training import (
    AdamWConfig,
    TrainConfig,
    cosine_schedule,
    init_adamw,
    lm_batches,
    load_checkpoint,
    save_checkpoint,
    train_loop,
    wsd_schedule,
)
from repro.training.optimizer import adamw_update, clip_by_global_norm, global_norm


def test_adamw_moves_toward_gradient():
    params = {"w": jnp.ones((4, 4))}
    grads = {"w": jnp.ones((4, 4))}
    state = init_adamw(params)
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, grad_clip=0.0)
    new, state = adamw_update(params, grads, state, cfg, jnp.asarray(0.1))
    assert bool(jnp.all(new["w"] < params["w"]))
    assert int(state.step) == 1


def test_weight_decay_only_on_matrices():
    params = {"w": jnp.ones((4, 4)), "b": jnp.ones((4,))}
    grads = {"w": jnp.zeros((4, 4)), "b": jnp.zeros((4,))}
    state = init_adamw(params)
    cfg = AdamWConfig(lr=0.1, weight_decay=0.1, grad_clip=0.0)
    new, _ = adamw_update(params, grads, state, cfg, jnp.asarray(0.1))
    assert bool(jnp.all(new["w"] < 1.0))  # decayed
    np.testing.assert_allclose(np.asarray(new["b"]), 1.0)  # not decayed


def test_grad_clip():
    g = {"a": jnp.full((10,), 100.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)
    assert float(norm) == pytest.approx(float(jnp.sqrt(10.0 * 100 ** 2)), rel=1e-5)


def test_cosine_schedule_shape():
    lr = cosine_schedule(1.0, warmup_steps=10, total_steps=100, min_ratio=0.1)
    assert float(lr(jnp.asarray(0))) == 0.0
    assert float(lr(jnp.asarray(10))) == pytest.approx(1.0, abs=1e-3)
    assert float(lr(jnp.asarray(100))) == pytest.approx(0.1, abs=1e-3)


def test_wsd_schedule_shape():
    """MiniCPM WSD: warmup, long flat stable stage, sharp final decay."""
    lr = wsd_schedule(1.0, warmup_steps=10, total_steps=100, decay_fraction=0.2)
    assert float(lr(jnp.asarray(5))) == pytest.approx(0.5)
    for s in (20, 50, 79):  # stable plateau
        assert float(lr(jnp.asarray(s))) == pytest.approx(1.0)
    assert float(lr(jnp.asarray(100))) == pytest.approx(0.01, abs=1e-3)
    assert float(lr(jnp.asarray(90))) < 1.0


def test_loss_decreases_on_synthetic_lm():
    cfg = reduced_config(get_config("llama3.2-1b"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    tcfg = TrainConfig(total_steps=80, warmup_steps=8, adamw=AdamWConfig(lr=1e-3))
    params, _, hist = train_loop(
        params, cfg, tcfg, lm_batches(cfg, batch=8, seq=64, seed=0),
        steps=80, log_every=79, log_fn=lambda s: None,
    )
    assert hist[-1]["loss"] < hist[0]["loss"] - 0.3


def test_checkpoint_roundtrip(tmp_path):
    cfg = reduced_config(get_config("qwen2-1.5b"))
    params = init_params(jax.random.PRNGKey(1), cfg)
    path = os.path.join(tmp_path, "ckpt")
    save_checkpoint(path, params, metadata={"arch": cfg.name})
    restored = load_checkpoint(path, jax.eval_shape(lambda: params))
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_shape_mismatch_raises(tmp_path):
    path = os.path.join(tmp_path, "ckpt")
    save_checkpoint(path, {"w": jnp.ones((2, 2))})
    with pytest.raises(ValueError):
        load_checkpoint(path, {"w": jax.ShapeDtypeStruct((3, 3), jnp.float32)})
