"""Unit tests for the completion-budget protocol (paper §4.5)."""

import math

import pytest

from repro.core.budget import TaskBudget
from repro.core.events import AcceptSignal, EventRecord, RejectSignal


def xi(b):  # affine cost model
    return 0.05 + 0.01 * b


def make_budget(**kw):
    return TaskBudget("T", xi, m_max=25, **kw)


def test_bootstrap_budget_is_infinite():
    tb = make_budget()
    assert math.isinf(tb.budget())
    assert math.isinf(tb.min_budget())


def test_reject_reduces_budget():
    tb = make_budget()
    tb.record(1, EventRecord(departure=1.0, queuing=0.4, batch_size=4, xi=xi(4)))
    new = tb.on_reject(RejectSignal(event_id=1, epsilon=0.5, q_bar=0.8))
    # lam = min(0.5 * 0.4/0.8, xi(4)-xi(1)) = min(0.25, 0.03) = 0.03
    assert new == pytest.approx(1.0 - 0.03)
    # A later, milder reject cannot increase it (min with beta_old).
    tb.record(2, EventRecord(departure=5.0, queuing=0.1, batch_size=2, xi=xi(2)))
    newer = tb.on_reject(RejectSignal(event_id=2, epsilon=0.1, q_bar=0.8))
    assert newer <= new or newer == pytest.approx(new)


def test_accept_increases_budget():
    tb = make_budget()
    tb.record(1, EventRecord(departure=1.0, queuing=0.2, batch_size=4, xi=xi(4)))
    new = tb.on_accept(AcceptSignal(event_id=1, epsilon=10.0, xi_bar=0.3))
    # share = 10 * xi(4)/0.3 = 3.0; headroom = 21*0.05 + xi(25)-xi(4) = 1.26
    # lam = min(3.0, 1.26) => beta = 1.0 + 1.26
    assert new == pytest.approx(1.0 + (25 - 4) * (0.2 / 4) + xi(25) - xi(4))
    # Out-of-order older accept with smaller value cannot reduce it.
    tb.record(2, EventRecord(departure=0.1, queuing=0.0, batch_size=1, xi=xi(1)))
    newer = tb.on_accept(AcceptSignal(event_id=2, epsilon=0.01, xi_bar=0.3))
    assert newer >= new


def test_first_signal_ignores_beta_old():
    tb = make_budget()
    tb.record(1, EventRecord(departure=2.0, queuing=0.5, batch_size=5, xi=xi(5)))
    # First signal is a reject: the budget is set directly (bootstrap).
    new = tb.on_reject(RejectSignal(event_id=1, epsilon=1.0, q_bar=1.0))
    assert new is not None and not math.isinf(new)


def test_unknown_event_signal_is_ignored():
    tb = make_budget()
    assert tb.on_reject(RejectSignal(event_id=99, epsilon=1.0, q_bar=1.0)) is None
    assert tb.on_accept(AcceptSignal(event_id=99, epsilon=1.0, xi_bar=1.0)) is None
    assert math.isinf(tb.budget())


def test_per_downstream_budgets_are_independent():
    tb = make_budget()
    tb.record(1, EventRecord(departure=1.0, queuing=0.4, batch_size=4, xi=xi(4)))
    tb.on_reject(RejectSignal(event_id=1, epsilon=0.5, q_bar=0.8), downstream="A")
    assert not math.isinf(tb.budget("A"))
    assert math.isinf(tb.budget("B"))
    assert tb.min_budget() == tb.budget("A")


def test_record_capacity_evicts_lru():
    tb = TaskBudget("T", xi, m_max=8, record_capacity=4)
    for k in range(10):
        tb.record(k, EventRecord(departure=1.0, queuing=0.1, batch_size=1, xi=xi(1)))
    assert tb.get_record(0) is None
    assert tb.get_record(9) is not None
