"""Property tests for the spotlight-search machinery (§2.3, Alg. 1).

Requires the optional ``hypothesis`` test dependency (declared in
pyproject.toml under ``[project.optional-dependencies] test``); the module
is skipped cleanly when it is not installed.

* :class:`ResumableDijkstra` resumed over an arbitrary nondecreasing radius
  schedule must match a from-scratch Dijkstra at every step.
* ``TLProbabilistic.spotlight_multi(use_kernel=True)`` (the bucket-batched
  CSR relaxation through ``repro.kernels.dispatch``) must match the
  incremental Python path on random multi-entity tracked states.
"""

import pytest

hypothesis = pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st

from repro.core.roadnet import ResumableDijkstra, make_road_network

# One fixed network per module: hypothesis then explores sources/radii/
# entity states, and (for the kernel path) every example shares a single
# (V, Q-bucket) jit specialization.
_NET = make_road_network(num_vertices=120, target_edges=340, seed=29)


# ----------------------------------------------------------------------- #
# Resumable Dijkstra == from-scratch ball over any increasing schedule     #
# ----------------------------------------------------------------------- #
@settings(max_examples=60, deadline=None, derandomize=True)
@given(
    source=st.integers(0, _NET.num_vertices - 1),
    increments=st.lists(st.floats(0.0, 600.0, allow_nan=False), min_size=1, max_size=8),
)
def test_resumable_dijkstra_matches_scratch_on_any_schedule(source, increments):
    search = ResumableDijkstra(_NET, source)
    radius = 0.0
    for inc in increments:
        radius += inc
        incremental = search.ball(radius)
        scratch = _NET.weighted_ball(source, radius)
        assert incremental == scratch
    # Settle order must stay nondecreasing in distance throughout.
    dists = [search._settled[v] for v in search.order]
    assert all(a <= b for a, b in zip(dists, dists[1:]))


# ----------------------------------------------------------------------- #
# Batched kernel path == incremental python path for multi-entity states   #
# ----------------------------------------------------------------------- #
# derandomize: the kernel path sums distances in float32 while the python
# path sums float64; a randomly drawn radius landing within one float32 ulp
# of a vertex distance could flip set membership.  The fixed example corpus
# keeps this a regression test rather than a lottery.
@settings(max_examples=25, deadline=None, derandomize=True)
@given(
    entities=st.lists(
        st.tuples(
            st.integers(0, _NET.num_vertices - 1),  # last-seen vertex
            st.floats(0.0, 30.0, allow_nan=False),  # last-seen time
        ),
        min_size=1,
        max_size=8,
        unique_by=lambda e: e[0],
    ),
    now_offset=st.floats(0.0, 120.0, allow_nan=False),
    coverage=st.floats(0.5, 1.0, allow_nan=False),
)
def test_spotlight_multi_kernel_matches_python(entities, now_offset, coverage):
    pytest.importorskip("jax")
    from repro.core.tracking import TLProbabilistic

    cams = {c: c for c in range(_NET.num_vertices)}
    tl = TLProbabilistic(_NET, cams, entity_speed=4.0, coverage=coverage)
    latest = 0.0
    for i, (vertex, t) in enumerate(entities):
        tl.track(f"e{i}", camera_id=vertex, timestamp=t)
        latest = max(latest, t)
    now = latest + now_offset
    python_set = tl.spotlight_multi(now)
    kernel_set = tl.spotlight_multi(now, use_kernel=True)
    assert kernel_set == python_set
