"""HLO cost analyzer: trip-count expansion validated against XLA's own
cost_analysis on unrolled modules; collective parsing on a known program."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_analysis import analyze_hlo
from repro.launch.roofline import HW, roofline_terms


def _flops(fn, *args):
    compiled = jax.jit(fn).lower(*args).compile()
    c = compiled.cost_analysis()
    if isinstance(c, (list, tuple)):
        c = c[0]
    return compiled, c


def test_scan_trip_count_expansion():
    def body(x, w):
        return jnp.tanh(x @ w), None

    def scanned(x, ws):
        return jax.lax.scan(body, x, ws)[0]

    def unrolled(x, ws):
        for i in range(ws.shape[0]):
            x, _ = body(x, ws[i])
        return x

    x = jax.ShapeDtypeStruct((128, 512), jnp.float32)
    ws = jax.ShapeDtypeStruct((8, 512, 512), jnp.float32)
    expected = 2 * 128 * 512 * 512 * 8

    c_scan, _ = _flops(scanned, x, ws)
    c_unr, xla_unr = _flops(unrolled, x, ws)
    h_scan = analyze_hlo(c_scan.as_text())
    h_unr = analyze_hlo(c_unr.as_text())

    assert h_scan.flops == pytest.approx(expected, rel=0.01)
    assert h_unr.flops == pytest.approx(expected, rel=0.01)
    assert h_unr.flops == pytest.approx(float(xla_unr["flops"]), rel=0.01)
    assert 8 in h_scan.while_trip_counts.values()


def test_bytes_reasonable_vs_xla_on_unrolled():
    def f(x, w):
        for _ in range(4):
            x = jnp.tanh(x @ w)
        return x

    x = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    w = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    compiled, xla = _flops(f, x, w)
    h = analyze_hlo(compiled.as_text())
    # our operand+result accounting is an upper-bound style approximation;
    # it should land within ~4x of XLA's unique-buffer count.
    assert h.bytes >= float(xla["bytes accessed"]) * 0.5
    assert h.bytes <= float(xla["bytes accessed"]) * 4.0


def test_in_place_dus_counts_update_only():
    def f(cache, new):
        return jax.lax.dynamic_update_slice(cache, new, (0, 5, 0))

    cache = jax.ShapeDtypeStruct((4, 100_000, 64), jnp.float32)
    new = jax.ShapeDtypeStruct((4, 1, 64), jnp.float32)
    compiled = jax.jit(f, donate_argnums=0).lower(cache, new).compile()
    h = analyze_hlo(compiled.as_text())
    update_bytes = 4 * 1 * 64 * 4
    assert h.bytes <= 10 * update_bytes  # NOT ~100MB (the full cache)


def test_dynamic_slice_counts_slice_only():
    def f(big, i):
        return jax.lax.dynamic_slice(big, (i, 0), (1, 64))

    big = jax.ShapeDtypeStruct((100_000, 64), jnp.float32)
    i = jax.ShapeDtypeStruct((), jnp.int32)
    compiled = jax.jit(f).lower(big, i).compile()
    h = analyze_hlo(compiled.as_text())
    assert h.bytes <= 20 * 64 * 4  # slice-sized, not 25 MB


def test_roofline_terms_dominance():
    # synthetic: compute-dominated numbers
    class FakeCosts:
        flops = 1e15
        bytes = 1e9
        collective_bytes = 1e6
        collective_by_kind = {"all-reduce": 1e6}
        collective_counts = {"all-reduce": 2.0}
        while_trip_counts = {}

    t = roofline_terms(
        arch="x", shape="y", mesh="z", chips=256, hlo_text="",
        model_flops=1e17, costs=FakeCosts(),
    )
    assert t.dominant == "compute"
    assert t.compute_s == pytest.approx(1e15 / HW().peak_flops)
    assert t.useful_ratio == pytest.approx(1e17 / (1e15 * 256))


def test_collective_parsing_from_dryrun_artifacts():
    """The recorded dry-run HLOs (if present) must contain collectives for
    model-parallel cases — sanity of the end-to-end plumbing."""
    import glob
    import json

    recs = glob.glob("experiments/dryrun/*train_4k__16x16.json")
    if not recs:
        pytest.skip("dry-run records not generated yet")
    with open(recs[0]) as f:
        rec = json.load(f)
    assert rec["roofline"]["coll_bytes"] > 0
    assert rec["roofline"]["flops"] > 0
    assert rec["chips"] == 256
