"""Fault-tolerant serving plane: crash/partition injection + journaled
checkpoint-restore (PR 6).

The headline goldens freeze the exact-recovery contract: a run whose driver
is killed at t=100 (after the t=90 snapshot), restored from the journal's
last snapshot, and replayed produces per-query summaries — and the full
journal digest — **bit-identical** to an uninterrupted seed-0 run, with
drops off AND on.  Fault losses are charged as the ``dp_fault`` class
through the same drop hook as DP1-3, so ``sourced == completed + dropped``
still reconciles exactly.
"""

import math
import os

import pytest

from repro.core.pipeline import DP_FAULT
from repro.query import MultiQueryScenario
from repro.serving.journal import Journal, RestoreMismatch, diff_snapshots
from repro.sim import ScenarioConfig, TrackingScenario
from repro.sim.dynamism import (
    DynamismSpec,
    FaultPlane,
    HostCrash,
    NetworkPartition,
    RetryPolicy,
)

CRASH = HostCrash(hosts=("node0",), t_start=60.0, outage_s=20.0)
PARTITION = NetworkPartition(
    group_a=("node", "head"), group_b=("edge",), t_start=60.0, t_end=80.0
)
T_KILL = 100.0  # driver killed here — after the t=90 snapshot
SNAP_PERIOD = 30.0


def _cfg(perturbation, drops: bool) -> ScenarioConfig:
    kw = dict(
        num_cameras=100,
        duration_s=120.0,
        seed=0,
        dynamism=DynamismSpec(perturbations=(perturbation,)),
    )
    if drops:
        kw.update(
            drops_enabled=True,
            avoid_drop_positives=True,
            tl_peak_speed=7.0,
            num_va=5,
            num_cr=5,
        )
    return ScenarioConfig(**kw)


# --------------------------------------------------------------------- #
# Frozen goldens (the CI fault smoke gates on these digests)             #
# --------------------------------------------------------------------- #
GOLDEN_CRASH_OFF = {
    "source_events": 1416,
    "on_time": 1394,
    "delayed": 0,
    "dropped": 22,
    "delayed_frac": 0.0,
    "dropped_frac": 0.0155,
    "median_latency_s": 0.157,
    "p99_latency_s": 0.517,
    "peak_active": 25,
    "positives_generated": 19,
    "positives_completed": 14,
    "truth_events": 19,
    "track_recall": 0.7368,
    "track_precision": 1.0,
}
GOLDEN_CRASH_OFF_DROPS = {"dp_fault": 22}
GOLDEN_CRASH_OFF_DIGEST = (
    "19293594d747e4bea7af178ca3f8d508fff6421719cd475f4095b9cac59f8ea7"
)

GOLDEN_CRASH_ON = {
    "source_events": 2843,
    "on_time": 2054,
    "delayed": 0,
    "dropped": 789,
    "delayed_frac": 0.0,
    "dropped_frac": 0.2775,
    "median_latency_s": 4.669,
    "p99_latency_s": 13.79,
    "peak_active": 63,
    "positives_generated": 19,
    "positives_completed": 14,
    "truth_events": 19,
    "track_recall": 0.7368,
    "track_precision": 1.0,
}
GOLDEN_CRASH_ON_DROPS = {"dp1": 4, "dp2": 649, "dp_fault": 136}
GOLDEN_CRASH_ON_DIGEST = (
    "98c2d7f22e96e8ae71f495054dee57c06db24500f377f3c526fba51aaaca6132"
)


# --------------------------------------------------------------------- #
# Fault injection: reconciliation under crash and partition              #
# --------------------------------------------------------------------- #
def test_host_crash_reconciles_exactly():
    sc = TrackingScenario(_cfg(CRASH, drops=False))
    res = sc.run()
    fp = sc.sim.faults
    assert fp is not None and fp.fault_drops > 0
    # Every sourced event is accounted: completed at the sink or lost to
    # the fault plane — nothing leaks, nothing is double-counted.
    assert res.source_events == res.on_time + res.delayed + res.dropped
    assert res.dropped == fp.fault_drops  # drops off: only fault losses
    assert sum(res.drops_by_task.values()) == fp.fault_drops


def test_partition_retries_then_drops_and_heals():
    sc = TrackingScenario(_cfg(PARTITION, drops=False))
    res = sc.run()
    fp = sc.sim.faults
    # Blocked sends were retried (seeded backoff) before being charged.
    assert fp.retries > 0 and fp.sends_blocked > 0
    assert fp.fault_drops > 0
    assert res.source_events == res.on_time + res.delayed + res.dropped
    # After the window heals, traffic flows again: completions outnumber
    # losses by a wide margin on a 20 s partition in a 120 s run.
    assert res.on_time > res.dropped


def test_fault_free_run_is_untouched():
    cfg = ScenarioConfig(num_cameras=100, duration_s=120.0, seed=0)
    sc = TrackingScenario(cfg)
    assert sc.sim.faults is None
    assert sc.sim.transit_is_static  # fast paths stay on without faults
    res = sc.run()
    assert res.dropped == 0
    assert res.source_events == res.on_time + res.delayed


def test_faults_must_install_before_build():
    sc = TrackingScenario(ScenarioConfig(num_cameras=100, duration_s=10.0, seed=0))
    with pytest.raises(RuntimeError, match="before building tasks"):
        sc.sim.faults = FaultPlane((CRASH,), ())


def test_fault_plane_predicates_and_retry_schedule():
    fp = FaultPlane((CRASH,), (PARTITION,), seed=0)
    assert fp.host_down("node0", 70.0)
    assert fp.host_down("node0", 60.0)  # closed start
    assert not fp.host_down("node0", 80.0)  # open end
    assert not fp.host_down("node1", 70.0)
    assert fp.link_blocked("edge3", "node0", 70.0)
    assert fp.link_blocked("node0", "edge3", 70.0)  # both directions
    assert not fp.link_blocked("node0", "head", 70.0)  # same side
    assert not fp.link_blocked("edge3", "node0", 90.0)  # healed
    assert fp.partition_active(70.0) and not fp.partition_active(90.0)
    # Retry delays: deterministic in the seed, capped exponential + jitter.
    r = RetryPolicy()
    delays = [fp.retry_delay(a) for a in range(8)]
    assert all(d >= r.timeout_s for d in delays)
    assert max(delays) <= r.timeout_s + r.cap_s * (1.0 + r.jitter)
    fp2 = FaultPlane((CRASH,), (PARTITION,), seed=0)
    assert [fp2.retry_delay(a) for a in range(8)] == delays


def test_fault_spec_validation():
    with pytest.raises(ValueError):
        HostCrash(hosts=(), t_start=1.0)
    with pytest.raises(ValueError):
        HostCrash(outage_s=0.0)
    with pytest.raises(ValueError):
        NetworkPartition(group_a=())
    with pytest.raises(ValueError):
        NetworkPartition(t_start=10.0, t_end=5.0)
    with pytest.raises(ValueError):
        RetryPolicy(max_retries=-1)
    # Crash/partition windows feed the spec's window discovery (so
    # budget_recovery splits pre/during/post automatically).
    spec = DynamismSpec(perturbations=(CRASH, PARTITION))
    assert (60.0, 80.0) in spec.windows()
    assert spec.fault_plane(seed=0) is not None
    assert DynamismSpec().fault_plane(seed=0) is None


# --------------------------------------------------------------------- #
# Headline goldens: crash at T, restore, replay => bit-identical         #
# --------------------------------------------------------------------- #
def _crash_restore_run(drops: bool):
    # Uninterrupted reference.
    ref = MultiQueryScenario(_cfg(CRASH, drops), 3, journal=Journal(SNAP_PERIOD))
    ref_res = ref.run()
    # Crashed driver: killed at T_KILL; only its journal survives.
    crashed = MultiQueryScenario(_cfg(CRASH, drops), 3, journal=Journal(SNAP_PERIOD))
    crashed.run_until(T_KILL)
    wal = crashed.journal
    assert wal.last_snapshot()["time"] == 90.0
    del crashed
    # Recovery: fresh build, replay to the snapshot, verify, continue.
    rec = MultiQueryScenario(_cfg(CRASH, drops), 3, journal=Journal(SNAP_PERIOD))
    rec.restore(wal)
    assert rec.sim.time == 90.0
    rec_res = rec.run()
    return ref, ref_res, rec, rec_res


@pytest.mark.parametrize(
    "drops,golden,golden_drops,golden_digest",
    [
        (False, GOLDEN_CRASH_OFF, GOLDEN_CRASH_OFF_DROPS, GOLDEN_CRASH_OFF_DIGEST),
        (True, GOLDEN_CRASH_ON, GOLDEN_CRASH_ON_DROPS, GOLDEN_CRASH_ON_DIGEST),
    ],
    ids=["drops-off", "drops-on"],
)
def test_golden_crash_restore_bit_identical(drops, golden, golden_drops, golden_digest):
    ref, ref_res, rec, rec_res = _crash_restore_run(drops)
    # The recovered run equals the uninterrupted one, query by query.
    for qid in ref_res.per_query:
        assert rec_res.per_query_summary(qid) == ref_res.per_query_summary(qid)
        assert (
            rec_res.per_query[qid].drops_by_task
            == ref_res.per_query[qid].drops_by_task
        )
    # The full observable event stream matches too, not just the summaries.
    assert rec.journal.digest() == ref.journal.digest()
    # And both match the frozen golden (identical queries share one view).
    assert ref_res.per_query_summary(0) == golden
    assert ref_res.per_query[0].drops_by_task == golden_drops
    assert ref.journal.digest() == golden_digest
    # Per-query books reconcile exactly, dp_fault included.
    for st in ref_res.registry.states.values():
        assert st.sourced == st.completed + st.dropped + st.orphan_completed
        assert st.dropped == sum(st.dp[1:])
        if not drops:
            assert st.dp[1] == st.dp[2] == st.dp[3] == 0  # only dp_fault


def test_restore_rejects_diverged_snapshot():
    sc = MultiQueryScenario(_cfg(CRASH, False), 3, journal=Journal(SNAP_PERIOD))
    sc.run_until(T_KILL)
    snap = dict(sc.journal.last_snapshot())
    snap["source_events"] += 1.0  # corrupt one counter
    rec = MultiQueryScenario(_cfg(CRASH, False), 3, journal=Journal(SNAP_PERIOD))
    with pytest.raises(RestoreMismatch, match="source_events"):
        rec.restore(snap)


def test_restore_requires_fresh_scenario():
    sc = MultiQueryScenario(_cfg(CRASH, False), 3, journal=Journal(SNAP_PERIOD))
    sc.run_until(50.0)
    with pytest.raises(RuntimeError, match="freshly built"):
        sc.restore({"time": 30.0})


def test_journal_npz_round_trip(tmp_path):
    sc = MultiQueryScenario(_cfg(CRASH, False), 3, journal=Journal(SNAP_PERIOD))
    sc.run_until(T_KILL)
    wal = sc.journal
    path = os.path.join(str(tmp_path), "wal")
    wal.save(path)
    # Same-shape journal restores bit-exactly through the training-plane
    # checkpoint validation (missing AND unexpected keys fail loudly).
    clone = Journal(SNAP_PERIOD)
    clone.records = list(wal.records)
    clone.snapshots = [dict(s) for s in wal.snapshots]
    clone.load(path)
    assert clone.digest() == wal.digest()
    assert clone.counts() == wal.counts()
    # A journal with a different shape is rejected, not silently truncated.
    short = Journal(SNAP_PERIOD)
    short.records = list(wal.records)[:-1]
    short.snapshots = [dict(s) for s in wal.snapshots]
    with pytest.raises((KeyError, ValueError)):
        short.load(path)


def test_compiled_app_snapshot_restore_gate():
    sc = MultiQueryScenario(_cfg(CRASH, False), 3)
    sc.run_until(T_KILL)
    snap = sc.compiled.snapshot()
    assert sc.compiled.restore(snap) is sc.compiled  # self-match passes
    bad = dict(snap)
    key = next(k for k in bad if k.endswith("::arrived"))
    bad[key] += 1.0
    with pytest.raises(RestoreMismatch):
        sc.compiled.restore(bad)


def test_diff_snapshots_reports_all_kinds():
    a = {"x": 1.0, "y": 2.0}
    b = {"x": 1.0, "z": 3.0}
    diff = diff_snapshots(a, b)
    assert any("y" in d and "missing" in d for d in diff)
    assert any("z" in d and "unexpected" in d for d in diff)
    assert diff_snapshots(a, dict(a)) == []


# --------------------------------------------------------------------- #
# Admission: shed to queue while partitioned, requeue FIFO on heal       #
# --------------------------------------------------------------------- #
def test_admission_sheds_during_partition_and_requeues_on_heal():
    from repro.query import AdmissionPolicy, QuerySpec

    part = NetworkPartition(
        group_a=("node", "head"), group_b=("edge",), t_start=30.0, t_end=60.0
    )
    cfg = ScenarioConfig(
        num_cameras=100,
        duration_s=120.0,
        seed=0,
        dynamism=DynamismSpec(perturbations=(part,)),
    )
    specs = [QuerySpec(), QuerySpec(submit_at=40.0), QuerySpec(submit_at=45.0)]
    sc = MultiQueryScenario(cfg, specs, admission=AdmissionPolicy())
    res = sc.run()
    stats = res.admission.stats()
    # Mid-partition submissions were shed to the queue, not admitted...
    assert stats["adm_queued"] == 2
    # ...and requeued FIFO once the window healed (TL control cadence).
    assert stats["adm_requeued"] == 2
    assert stats["adm_queue_left"] == 0
    assert stats["adm_rejected"] == 0
    for qid in (1, 2):
        st = res.registry.get(qid)
        assert st.scoped_at is not None and st.scoped_at >= 60.0


def test_admission_partition_shedding_can_be_disabled():
    from repro.query import AdmissionController, AdmissionPolicy

    class _Sim:
        faults = FaultPlane((), (PARTITION,))
        time = 70.0  # inside the partition window

    class _Scenario:
        sim = _Sim()

        class app:
            gamma = 15.0

    on = AdmissionController(AdmissionPolicy())
    off = AdmissionController(AdmissionPolicy(shed_on_partition=False))
    assert not on.admittable(_Scenario, 0)
    assert off.admittable(_Scenario, 0)
    _Sim.time = 90.0  # healed
    assert on.admittable(_Scenario, 0)


# --------------------------------------------------------------------- #
# dp_fault plumbing                                                      #
# --------------------------------------------------------------------- #
def test_dp_fault_constant_and_stats_sum():
    from repro.core.pipeline import PipelineStats

    assert DP_FAULT == 4
    s = PipelineStats(dropped_dp1=1, dropped_dp2=2, dropped_dp3=3, dropped_fault=4)
    assert s.dropped == 10


def test_crash_restart_resumes_host():
    """After the outage the crashed host serves again: a later window sees
    completions from tasks on node0."""
    crash = HostCrash(hosts=("node0",), t_start=30.0, outage_s=10.0)
    assert crash.host_down("node0", 35.0)
    assert not crash.host_down("node0", 45.0)  # restarted
    assert crash.window() == (30.0, 40.0)
    sc = TrackingScenario(_cfg(crash, drops=False))
    res = sc.run()
    # Post-restart the pipeline drains normally — the run still completes
    # the overwhelming majority of its events.
    assert res.on_time > 0.9 * res.source_events
