"""Property tests for the multi-query tenancy plane.

Requires the optional ``hypothesis`` test dependency (skipped cleanly when
missing, like the other ``*_props`` modules).

Over random query submit/cancel schedules the fused driver must keep its
books: masks only ever tag queries that are live at source time (so no
event *executes for* an expired/cancelled query — anything in flight when a
query ends is orphan-accounted, never attributed), and every per-query
counter reconciles exactly with the shared pipeline's ``ScenarioResult``
after the drain window.
"""

import pytest

hypothesis = pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st

from repro.query import MultiQueryScenario, QuerySpec
from repro.sim import ScenarioConfig

DURATION = 40.0

# One world key for every example: the process-wide world cache makes each
# hypothesis example pay scenario construction only, not geometry builds.
def _cfg():
    return ScenarioConfig(num_cameras=100, duration_s=DURATION, seed=0,
                          tl="bfs", batching="dynamic", m_max=25)


@st.composite
def schedules(draw):
    n = draw(st.integers(1, 5))
    specs = []
    for i in range(n):
        submit = draw(st.floats(0.0, DURATION * 0.75, allow_nan=False))
        if draw(st.booleans()):
            cancel = draw(
                st.floats(submit + 0.5, DURATION + 5.0, allow_nan=False)
            )
        else:
            cancel = None
        specs.append(
            QuerySpec(
                submit_at=submit,
                cancel_at=cancel,
                tl_peak_speed=draw(st.sampled_from([3.0, 4.0, 6.0])),
                last_seen_camera=draw(
                    st.one_of(st.none(), st.integers(0, 99))
                ),
            )
        )
    return specs


@settings(max_examples=25, deadline=None, derandomize=True)
@given(specs=schedules())
def test_random_schedules_keep_the_books(specs):
    res = MultiQueryScenario(_cfg(), specs).run()
    reg = res.registry
    base = res.result

    # Global sink accounting is untouched by the tenancy plane.
    assert base.on_time + base.delayed == len(base.latencies)

    total_attr_completed = 0
    for qid, st_q in reg.states.items():
        # Reconciliation: after the drain window (duration + 3 gamma, drops
        # off) every sourced event either completed or orphaned.
        assert st_q.dropped == 0
        assert (
            st_q.sourced
            == st_q.completed + st_q.orphan_completed
        ), (qid, reg.reconcile())
        total_attr_completed += st_q.completed

        spec = st_q.spec
        # Lifecycle windows: nothing attributed before activation or after
        # the end — "no event executes for an expired query".
        if st_q.scoped_at is not None:
            assert all(t >= st_q.scoped_at for t, _ in st_q.latencies)
        else:
            assert st_q.sourced == 0 and st_q.completed == 0
        if st_q.ended_at is not None:
            assert all(t <= st_q.ended_at for t, _ in st_q.latencies)
            assert st_q.applied == set() or st_q.state == "found"
        # found_at implies at least one positive attribution.
        if st_q.found_at is not None:
            assert st_q.positives_completed > 0

    # Every completion the queries claim happened at the shared sink; an
    # event tagged for k queries is attributed (up to) k times.
    assert total_attr_completed <= len(base.latencies) * max(len(specs), 1)
    # Each event was sourced for at least one query, so the per-query sum
    # bounds the global count from above.
    per_q_sourced = sum(s.sourced for s in reg.states.values())
    assert base.source_events <= per_q_sourced or base.source_events == 0

    # Terminal states are only ever the declared lifecycle states.
    assert all(
        s.state in ("submitted", "scoped", "found", "expired", "cancelled")
        for s in reg.states.values()
    )


@settings(max_examples=10, deadline=None, derandomize=True)
@given(
    n=st.integers(1, 4),
    cancel_at=st.floats(5.0, 35.0, allow_nan=False),
)
def test_cancel_mid_run_frees_cameras_and_masks(n, cancel_at):
    """After a cancellation no new events are tagged for the dead query:
    its sourced counter freezes at (completed + orphans), and the camera
    mask map carries no live bit for it."""
    specs = [QuerySpec()] + [
        QuerySpec(submit_at=1.0 * i, cancel_at=cancel_at) for i in range(n)
    ]
    scenario = MultiQueryScenario(_cfg(), specs)
    res = scenario.run()
    for qid, st_q in res.registry.states.items():
        if st_q.state == "cancelled":
            assert st_q.sourced == st_q.completed + st_q.orphan_completed
            # The mask map holds no live bit for a dead query.
            assert all(
                not (mask & st_q.bit)
                for mask in scenario._mask_of.values()
            ) or st_q.applied == set()
    # The always-live query ran to the end.
    assert res.registry.get(0).state in ("scoped", "found")
