"""Replay-safety analyzer gate: every rule fires on its positive fixture,
stays quiet on the negative one, suppressions and the baseline behave, and
the tree itself scans clean (modulo the committed baseline).

The fixtures are the rule *spec*: if a rule's behaviour changes, these
snippets are the contract that changed.
"""

import json
import os
from pathlib import Path

import pytest

from repro.analysis import (
    filter_baselined,
    load_baseline,
    rule_catalog,
    scan_paths,
    scan_source,
)
from repro.analysis.__main__ import main as cli_main

REPO = Path(__file__).resolve().parents[1]


def rules_of(text, path="core/x.py", **kw):
    return sorted({f.rule for f in scan_source(text, path, **kw)})


def lines_of(text, rule, path="core/x.py", **kw):
    return [f.line for f in scan_source(text, path, **kw) if f.rule == rule]


# --------------------------------------------------------------------- #
# DET001 — unordered set iteration feeding scheduling / accumulation     #
# --------------------------------------------------------------------- #
DET001_POS = """\
def tick(sim, cams):
    for c in set(cams):
        sim.schedule(0.1, c)
    total = 0.0
    for w in {1.0, 2.0, 3.0}:
        total += w
    return total + sum(x for x in set(cams))
"""

DET001_NEG = """\
def tick(sim, cams, table):
    for c in sorted(set(cams)):          # ordered: fine
        sim.schedule(0.1, c)
    for k in table:                      # dict: insertion-ordered, fine
        table[k] += 1.0
    names = {c.name for c in cams}       # set built, never driving order
    return names
"""


def test_det001_fires_on_set_iteration_feeding_schedule():
    assert rules_of(DET001_POS) == ["DET001"]
    assert len(lines_of(DET001_POS, "DET001")) == 3


def test_det001_quiet_on_sorted_and_dict_iteration():
    assert rules_of(DET001_NEG) == []


def test_det001_scoped_to_scheduling_planes():
    # The same code outside core/sim/query (e.g. launch/) is not flagged.
    assert rules_of(DET001_POS, path="launch/x.py") == []


# --------------------------------------------------------------------- #
# DET002 — wall-clock reads                                              #
# --------------------------------------------------------------------- #
DET002_POS = """\
import time
from datetime import datetime

def stamp():
    a = time.time()
    b = datetime.now()
    return a, b
"""

DET002_NEG = """\
import time
from repro.core.clock import monotonic

def stamp():
    return monotonic(), time.perf_counter()
"""


def test_det002_fires_on_wall_clock_reads():
    assert rules_of(DET002_POS) == ["DET002"]
    assert len(lines_of(DET002_POS, "DET002")) == 2


def test_det002_quiet_on_monotonic():
    assert rules_of(DET002_NEG) == []


def test_det002_catches_from_import_alias():
    src = "from time import time as wall\nx = wall()\n"
    assert rules_of(src) == ["DET002"]


# --------------------------------------------------------------------- #
# DET003 — unseeded global RNG                                           #
# --------------------------------------------------------------------- #
DET003_POS = """\
import random
import numpy as np
from random import randint

def jitter():
    return random.random() + np.random.rand() + randint(1, 5)
"""

DET003_NEG = """\
import random
import numpy as np

def jitter(seed):
    rng = random.Random(seed)
    g = np.random.default_rng(seed)
    return rng.random() + g.standard_normal()
"""


def test_det003_fires_on_global_rng():
    assert rules_of(DET003_POS) == ["DET003"]
    assert len(lines_of(DET003_POS, "DET003")) == 3


def test_det003_quiet_on_seeded_generators():
    assert rules_of(DET003_NEG) == []


# --------------------------------------------------------------------- #
# DET004 — id()/hash() sort keys                                         #
# --------------------------------------------------------------------- #
def test_det004_fires_on_identity_sort_keys():
    pos = "a = sorted(xs, key=id)\nxs.sort(key=lambda o: hash(o))\n"
    assert rules_of(pos) == ["DET004"]
    assert len(lines_of(pos, "DET004")) == 2


def test_det004_quiet_on_stable_keys():
    neg = "a = sorted(xs, key=len)\nxs.sort(key=lambda o: o.name)\n"
    assert rules_of(neg) == []


# --------------------------------------------------------------------- #
# JAX001 — jit/pallas constructed outside the bound_jit_cache contract   #
# --------------------------------------------------------------------- #
JAX001_POS = """\
import jax

def dispatch(fn, x):
    step = jax.jit(fn)          # fresh compile cache per call
    return step(x)
"""

JAX001_NEG = """\
import functools
import jax

step = jax.jit(lambda x: x)     # module scope: constructed once

@jax.jit
def f(x):
    return x

@functools.partial(jax.jit, static_argnames=("n",))
def g(x, n):
    return x * n
"""


def test_jax001_fires_on_in_function_jit_construction():
    assert rules_of(JAX001_POS, path="kernels/foo/ops.py") == ["JAX001"]


def test_jax001_quiet_on_module_scope_and_decorators():
    assert rules_of(JAX001_NEG, path="kernels/foo/ops.py") == []


def test_jax001_exempts_bound_jit_cache_modules_and_kernel_defs():
    contract = "from ..dispatch import bound_jit_cache\n" + JAX001_POS
    assert rules_of(contract, path="kernels/foo/ops.py") == []
    pallas = (
        "import jax\nfrom jax.experimental import pallas as pl\n"
        "def run(x, interpret=False):\n"
        "    return pl.pallas_call(x, interpret=interpret)\n"
    )
    assert rules_of(pallas, path="kernels/foo/kernel.py") == []
    assert "JAX001" in rules_of(pallas, path="sim/bad.py")


# --------------------------------------------------------------------- #
# JAX002 — implicit host pulls in traced code                            #
# --------------------------------------------------------------------- #
JAX002_POS = """\
import jax
import numpy as np
from jax import lax

@jax.jit
def f(x):
    return x.item()

def outer(xs):
    def body(c, x):
        return c + float(np.asarray(x)), None
    return lax.scan(body, 0.0, xs)
"""

JAX002_NEG = """\
import jax
import numpy as np

def pad_and_run(fn, x):
    x = np.asarray(x)           # host-side prep before the jit boundary
    y = fn(x)
    return float(y)             # pull after the boundary
"""


def test_jax002_fires_inside_traced_functions():
    got = lines_of(JAX002_POS, "JAX002", path="kernels/foo/ops.py")
    assert len(got) == 3  # .item(), float(...), np.asarray(...)


def test_jax002_quiet_outside_traces():
    assert rules_of(JAX002_NEG, path="kernels/foo/ops.py") == []


# --------------------------------------------------------------------- #
# JAX003 — f32 accumulation under the mega-step f64 contract             #
# --------------------------------------------------------------------- #
JAX003_POS = """\
import jax.numpy as jnp

def books(rows):
    acc = jnp.zeros((4,), dtype=jnp.float32)
    return acc + rows.astype(jnp.float32).sum()
"""


def test_jax003_fires_only_in_the_megastep_plane():
    assert rules_of(JAX003_POS, path="kernels/megastep/ops.py") == ["JAX003"]
    assert rules_of(JAX003_POS, path="core/megastep.py") == ["JAX003"]
    # Other kernels own their dtype (f32 embeddings are the contract there).
    assert rules_of(JAX003_POS, path="kernels/reid_match/ops.py") == []


def test_jax003_quiet_on_f64():
    neg = JAX003_POS.replace("float32", "float64")
    assert rules_of(neg, path="kernels/megastep/ops.py") == []


# --------------------------------------------------------------------- #
# JAX004 — un-shimmed shard_map imports                                  #
# --------------------------------------------------------------------- #
JAX004_EXPERIMENTAL = """\
from jax.experimental.shard_map import shard_map

def wrap(fn, mesh, specs):
    return shard_map(fn, mesh=mesh, check_rep=False, **specs)
"""

JAX004_NEW_API = """\
import jax

def wrap(fn, mesh, specs):
    return jax.shard_map(fn, mesh=mesh, check_vma=False, **specs)
"""

JAX004_SHIMMED = """\
from repro.distributed.compat import shard_map

def wrap(fn, mesh, specs):
    return shard_map(fn, mesh=mesh, check=False, **specs)
"""


def test_jax004_fires_on_unshimmed_shard_map():
    assert "JAX004" in rules_of(
        JAX004_EXPERIMENTAL, path="distributed/context_parallel.py"
    )
    assert "JAX004" in rules_of(
        JAX004_NEW_API, path="kernels/megastep/sharded.py"
    )


def test_jax004_quiet_on_the_shim_and_its_users():
    assert rules_of(JAX004_SHIMMED, path="kernels/megastep/sharded.py") == []
    # The shim itself is the one sanctioned probe site.
    assert "JAX004" not in rules_of(
        JAX004_NEW_API, path="distributed/compat.py"
    )


# --------------------------------------------------------------------- #
# EXC001 — silent broad excepts                                          #
# --------------------------------------------------------------------- #
EXC001_POS = """\
def load(path):
    try:
        return open(path)
    except Exception:
        return None

def tick(fn):
    try:
        fn()
    except:
        pass
"""

EXC001_NEG = """\
def load(path, log):
    try:
        return open(path)
    except OSError:
        return None          # narrow: fine

def tick(fn, log):
    try:
        fn()
    except Exception as e:
        log(e)               # recorded: fine

def strict(fn):
    try:
        fn()
    except Exception:
        raise                # re-raised: fine
"""


def test_exc001_fires_on_silent_broad_excepts():
    assert rules_of(EXC001_POS) == ["EXC001"]
    assert len(lines_of(EXC001_POS, "EXC001")) == 2


def test_exc001_quiet_on_narrow_recorded_or_reraised():
    assert rules_of(EXC001_NEG) == []


# --------------------------------------------------------------------- #
# OBS001 — metric registrations must be literal repro_* names with help   #
# --------------------------------------------------------------------- #
OBS001_POS = """\
def publish(registry, name):
    registry.counter(name, "computed name: invisible to the catalog")
    registry.gauge("bad-name!", "name outside the repro_ namespace")
    registry.histogram("repro_latency_seconds")
"""

OBS001_NEG = """\
def publish(registry):
    c = registry.counter(
        "repro_events_total", "Events by task.", labels=("task",)
    )
    c.inc(3, task="VA")
    registry.gauge("repro_queue_depth", help="Current queue depth.")
    registry.histogram("repro_latency_seconds", "End-to-end latency.")
"""


def test_obs001_fires_on_unauditable_registrations():
    assert rules_of(OBS001_POS) == ["OBS001"]
    # computed name; bad name; missing help — one finding each.
    assert len(lines_of(OBS001_POS, "OBS001")) == 3


def test_obs001_quiet_on_literal_registrations():
    assert rules_of(OBS001_NEG) == []


def test_obs001_exempts_the_metrics_module_itself():
    assert rules_of(OBS001_POS, path="obs/metrics.py") == []


# --------------------------------------------------------------------- #
# Suppressions                                                           #
# --------------------------------------------------------------------- #
def test_noqa_same_line_suppresses():
    src = "import time\nt = time.time()  # repro: noqa[DET002]\n"
    assert rules_of(src) == []


def test_noqa_comment_above_suppresses():
    src = (
        "import time\n"
        "# repro: noqa[DET002] — benchmark wall clock, outside the DES\n"
        "t = time.time()\n"
    )
    assert rules_of(src) == []


def test_noqa_wrong_rule_does_not_suppress():
    src = "import time\nt = time.time()  # repro: noqa[EXC001]\n"
    assert rules_of(src) == ["DET002"]


def test_noqa_list_suppresses_multiple():
    src = (
        "import time, random\n"
        "t = time.time() + random.random()  # repro: noqa[DET002,DET003]\n"
    )
    assert rules_of(src) == []


# --------------------------------------------------------------------- #
# KRN — kernel-contract tree checks                                      #
# --------------------------------------------------------------------- #
GOOD_KERNEL = """\
from jax.experimental import pallas as pl

def foo_pallas(x, *, interpret=False):
    return pl.pallas_call(_kern, interpret=interpret)(x)

def _kern(ref):
    pass
"""
GOOD_REF = "def foo_ref(x):\n    return x\n"
GOOD_OPS = "def foo(x):\n    return x\n"


def _make_kernel_pkg(root, name, kernel=GOOD_KERNEL, ref=GOOD_REF,
                     ops=GOOD_OPS, skip=()):
    pkg = root / "kernels" / name
    pkg.mkdir(parents=True)
    for fname, text in (("kernel.py", kernel), ("ref.py", ref), ("ops.py", ops)):
        if fname not in skip:
            (pkg / fname).write_text(text)
    return pkg


def _krn_scan(root, tests_dir=None):
    return [
        f for f in scan_paths([str(root)], tests_dir=tests_dir)
        if f.rule.startswith("KRN")
    ]


def test_krn_clean_triple_passes(tmp_path):
    _make_kernel_pkg(tmp_path, "foo")
    tests = tmp_path / "tests"
    tests.mkdir()
    (tests / "test_foo.py").write_text("from kernels.foo.ref import foo_ref\n")
    assert _krn_scan(tmp_path, tests_dir=str(tests)) == []


def test_krn001_missing_triple_member(tmp_path):
    _make_kernel_pkg(tmp_path, "foo", skip=("ref.py",))
    found = _krn_scan(tmp_path)
    assert [f.rule for f in found] == ["KRN001"]
    assert "ref.py" in found[0].message


def test_krn002_ref_importing_pallas(tmp_path):
    bad_ref = "from jax.experimental import pallas as pl\ndef foo_ref(x):\n    return x\n"
    _make_kernel_pkg(tmp_path, "foo", ref=bad_ref)
    assert [f.rule for f in _krn_scan(tmp_path)] == ["KRN002"]


def test_krn003_kernel_not_interpret_gated(tmp_path):
    bad_kernel = (
        "from jax.experimental import pallas as pl\n"
        "def foo_pallas(x):\n"
        "    return pl.pallas_call(_kern)(x)\n"
        "def _kern(ref):\n    pass\n"
    )
    _make_kernel_pkg(tmp_path, "foo", kernel=bad_kernel)
    assert [f.rule for f in _krn_scan(tmp_path)] == ["KRN003"]


def test_krn004_unreferenced_kernel(tmp_path):
    _make_kernel_pkg(tmp_path, "foo")
    tests = tmp_path / "tests"
    tests.mkdir()
    (tests / "test_other.py").write_text("def test_nothing():\n    pass\n")
    assert [f.rule for f in _krn_scan(tmp_path, tests_dir=str(tests))] == ["KRN004"]


# --------------------------------------------------------------------- #
# Baseline                                                               #
# --------------------------------------------------------------------- #
def test_baseline_requires_justifications(tmp_path):
    p = tmp_path / "b.json"
    p.write_text(json.dumps([{"rule": "DET002", "path": "x.py", "line": 2}]))
    with pytest.raises(ValueError, match="justification"):
        load_baseline(str(p))


def test_baseline_filters_known_and_reports_stale(tmp_path):
    findings = scan_source(DET002_POS, "core/x.py")
    entry = {
        "rule": findings[0].rule, "path": findings[0].path,
        "line": findings[0].line, "justification": "grandfathered",
    }
    stale_entry = {
        "rule": "DET002", "path": "core/gone.py", "line": 9,
        "justification": "file was deleted",
    }
    new, stale = filter_baselined(findings, [entry, stale_entry])
    assert len(new) == len(findings) - 1
    assert stale == [stale_entry]


# --------------------------------------------------------------------- #
# CLI                                                                    #
# --------------------------------------------------------------------- #
def test_cli_exits_nonzero_on_each_rule_family(tmp_path, capsys):
    cases = {
        "DET001": DET001_POS, "DET002": DET002_POS, "DET003": DET003_POS,
        "EXC001": EXC001_POS,
    }
    for rule, src in cases.items():
        # Place under a fake repro/core/ so package-scoped rules apply.
        f = tmp_path / "repro" / "core" / f"viol_{rule.lower()}.py"
        f.parent.mkdir(parents=True, exist_ok=True)
        f.write_text(src)
        assert cli_main([str(f)]) == 1, rule
        out = capsys.readouterr().out
        assert rule in out


def test_cli_exits_zero_on_clean_tree(tmp_path, capsys):
    f = tmp_path / "clean.py"
    f.write_text("x = 1\n")
    assert cli_main([str(f)]) == 0
    assert "0 new finding(s)" in capsys.readouterr().out


def test_cli_select_restricts_rules(tmp_path, capsys):
    f = tmp_path / "repro" / "core" / "viol.py"
    f.parent.mkdir(parents=True)
    f.write_text(DET002_POS + EXC001_POS)
    assert cli_main([str(f), "--select", "EXC001"]) == 1
    out = capsys.readouterr().out
    assert "EXC001" in out and "DET002" not in out


def test_cli_missing_path_is_usage_error(capsys):
    assert cli_main(["definitely/not/here.py"]) == 2


def test_cli_list_rules_covers_every_family(capsys):
    assert cli_main(["--list-rules"]) == 0
    out = capsys.readouterr().out
    for rid in ("DET001", "DET002", "DET003", "DET004", "JAX001", "JAX002",
                "JAX003", "EXC001", "KRN001", "KRN002", "KRN003", "KRN004"):
        assert rid in out


def test_cli_write_baseline_roundtrip(tmp_path, capsys, monkeypatch):
    f = tmp_path / "repro" / "core" / "viol.py"
    f.parent.mkdir(parents=True)
    f.write_text(DET002_POS)
    b = tmp_path / "baseline.json"
    assert cli_main([str(f), "--baseline", str(b), "--write-baseline"]) == 0
    entries = json.loads(b.read_text())
    assert entries and entries[0]["rule"] == "DET002"
    # Unjustified snapshot entries are rejected until a human fills them in.
    assert cli_main([str(f), "--baseline", str(b)]) == 2
    for e in entries:
        e["justification"] = "fixture: grandfathered for the roundtrip test"
    b.write_text(json.dumps(entries))
    assert cli_main([str(f), "--baseline", str(b)]) == 0


# --------------------------------------------------------------------- #
# Self-scan: the tree itself holds the contract                          #
# --------------------------------------------------------------------- #
def test_self_scan_clean_modulo_committed_baseline():
    src = REPO / "src" / "repro"
    findings = scan_paths([str(src)], tests_dir=str(REPO / "tests"))
    baseline_path = REPO / "ANALYSIS_BASELINE.json"
    baseline = load_baseline(str(baseline_path))
    # Committed findings must be justified; path-normalize to the scan root.
    rel = [
        type(f)(f.rule, os.path.relpath(f.path, REPO).replace(os.sep, "/"),
                f.line, f.message)
        for f in findings
    ]
    new, _stale = filter_baselined(rel, baseline)
    assert new == [], "\n".join(f.render() for f in new)


def test_rule_catalog_is_documented():
    catalog = rule_catalog()
    doc = (REPO / "ANALYSIS.md").read_text()
    for rid in catalog:
        assert rid in doc, f"{rid} missing from ANALYSIS.md rule catalog"
