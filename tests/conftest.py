"""Force 8 emulated host devices before jax initializes.

``--xla_force_host_platform_device_count`` is read once, when the jax CPU
backend comes up, so it must be in the environment before any test module
imports jax — conftest import time is the only hook that early in a single
pytest process.  With 8 CPU devices visible, the sharded mega-step tests
build real 2/4/8-way meshes and exercise actual multi-device lowering +
collectives in-process; everything unsharded still runs on device 0 and is
unaffected.  Subprocess-based tests that need a *different* device count
(e.g. the cross-device-count digest invariance test) override XLA_FLAGS
themselves before importing jax.
"""

import os

_FLAG = "--xla_force_host_platform_device_count"
_flags = os.environ.get("XLA_FLAGS", "")
if _FLAG not in _flags:
    os.environ["XLA_FLAGS"] = (_flags + f" {_FLAG}=8").strip()
