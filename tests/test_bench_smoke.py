"""Benchmark-harness smoke + perf-regression gate (satellite of the sweep
engine PR).

* ``--only pipeline --smoke`` must finish in seconds, emit per-case and
  sweep records, and round-trip through ``--json``.
* ``--compare`` must pass against freshly generated same-machine records
  and fail when the baseline is made impossibly fast.
* The checked-in ``BENCH_pipeline.json`` smoke records gate drift at a
  loose tolerance by default (CI containers are noisy); the strict 35%
  gate — the PR's regression contract — runs when ``REPRO_RUN_SLOW=1``
  (slow-aware: it re-times the full-duration cases).
"""

import json
import os

import pytest

import benchmarks.run as benchrun
from benchmarks.scenarios import RECORDS

BENCH_JSON = os.path.join(os.path.dirname(__file__), "..", "BENCH_pipeline.json")


@pytest.fixture(autouse=True)
def _fresh_records(monkeypatch):
    # Keep harness runs hermetic: no on-disk world cache, fresh record list.
    monkeypatch.setenv("REPRO_WORLD_CACHE", "0")
    RECORDS.clear()
    yield
    RECORDS.clear()


def _run(argv):
    return benchrun.main(argv)


def test_pipeline_smoke_writes_records(tmp_path):
    out = tmp_path / "pipeline.json"
    status = _run(["--only", "pipeline", "--smoke", "--mode", "serial",
                   "--json", str(out)])
    assert status == 0
    data = json.loads(out.read_text())
    cases = {r["case"]: r for r in data["records"] if r["bench"] == "pipeline"}
    for name, _ in benchrun.PIPELINE_CASES:
        rec = cases[name]
        assert rec["mode"] == "smoke"
        assert rec["us_per_event"] > 0
        assert rec["run_s"] > 0 and rec["build_s"] >= 0


def test_dynamism_smoke_writes_records_and_shows_recovery(tmp_path):
    """The dynamism grid's acceptance contract: ``--only dynamism --smoke``
    runs DB/SB/NOB under the bandwidth-collapse and compute-slowdown
    perturbations deterministically at seed 0, records them, and the
    DynamicBatcher's CR budget recovers (post within 10% of pre) where the
    StaticBatcher's does not."""
    out = tmp_path / "dynamism.json"
    status = _run(["--only", "dynamism", "--smoke", "--mode", "serial",
                   "--json", str(out)])
    assert status == 0
    data = json.loads(out.read_text())
    cases = {r["case"]: r for r in data["records"] if r["bench"] == "dynamism"}
    expected = {
        f"{p}_{b}"
        for p in ("bwcollapse", "cpuslow")
        for b in ("DB-25", "SB-20", "NOB-25")
    }
    assert expected <= set(cases)

    def recovery(case):
        derived = dict(
            kv.split("=", 1) for kv in cases[case]["derived"].split(";") if "=" in kv
        )
        return float(derived["beta_recovery"])

    for perturb in ("bwcollapse", "cpuslow"):
        assert recovery(f"{perturb}_DB-25") >= 0.9, perturb
        assert recovery(f"{perturb}_SB-20") < 0.9, perturb


def test_queries_smoke_shows_fusion_and_admission_shedding(tmp_path):
    """The multi-query grid's acceptance contract: ``--only queries
    --smoke`` (a) runs the fused N-query scaling sweep with per-query
    summaries bit-identical to the per-query-serial baseline, the fused run
    beating it on wall-clock, and (b) demonstrates admission control —
    under the ComputeSlowdown window with 64 submitted queries, the
    admission-on run's CR-tier budget recovers to >= 0.9 of its
    pre-perturbation value while the no-admission run's does not."""
    out = tmp_path / "queries.json"
    status = _run(["--only", "queries", "--smoke", "--mode", "serial",
                   "--json", str(out)])
    assert status == 0
    data = json.loads(out.read_text())
    cases = {r["case"]: r for r in data["records"] if r["bench"] == "queries"}

    def derived(case):
        return dict(
            kv.split("=", 1) for kv in cases[case]["derived"].split(";") if "=" in kv
        )

    for n in (1, 4, 16):
        d = derived(f"fused_N{n}")
        assert d["bit_identical"] == "True", (n, d)
    # Wall-clock: fused 16 queries through one pipeline beats 16 serial
    # runs.  The >= 3x acceptance bar is frozen for the full-mode record on
    # the 1000-camera world (see BENCH_pipeline.json test below); the smoke
    # bar is kept loose for noisy CI containers.
    assert float(derived("fused_N16")["speedup_x"]) >= 1.5

    on, off = derived("admission_on"), derived("admission_off")
    assert float(on["beta_recovery"]) >= 0.9, on
    assert float(off["beta_recovery"]) < 0.9, off
    # Shedding is visible: fewer live queries, some queued, less dropping.
    assert int(on["live_end"]) < int(off["live_end"])
    assert int(on["queued"]) > 0
    assert float(on["dropped_frac"]) < float(off["dropped_frac"])


def test_checked_in_baseline_freezes_fused_query_speedup():
    """BENCH_pipeline.json records the acceptance numbers: the fused
    16-query run on the 1000-camera world at >= 3x over 16 sequential
    single-query runs, bit-identical per-query summaries, and the
    admission on/off recovery split."""
    with open(BENCH_JSON) as f:
        data = json.load(f)
    recs = {
        (r["case"], r.get("mode", "full")): r
        for r in data["records"]
        if r["bench"] == "queries"
    }
    d16 = dict(
        kv.split("=", 1)
        for kv in recs[("fused_N16", "full")]["derived"].split(";")
        if "=" in kv
    )
    assert float(d16["speedup_x"]) >= 3.0
    assert d16["bit_identical"] == "True"
    for mode in ("full", "smoke"):
        on = dict(
            kv.split("=", 1)
            for kv in recs[("admission_on", mode)]["derived"].split(";")
            if "=" in kv
        )
        off = dict(
            kv.split("=", 1)
            for kv in recs[("admission_off", mode)]["derived"].split(";")
            if "=" in kv
        )
        assert float(on["beta_recovery"]) >= 0.9
        assert float(off["beta_recovery"]) < 0.9


def test_compare_gate_passes_against_fresh_records(tmp_path):
    out = tmp_path / "base.json"
    assert _run(["--only", "pipeline", "--smoke", "--mode", "serial",
                 "--json", str(out)]) == 0
    RECORDS.clear()
    # Same machine, moments later, generous tolerance: must pass.
    assert _run(["--compare", str(out), "--smoke", "--mode", "serial",
                 "--compare-tolerance", "3.0"]) == 0


def test_compare_gate_fails_on_regression(tmp_path):
    out = tmp_path / "base.json"
    assert _run(["--only", "pipeline", "--smoke", "--mode", "serial",
                 "--json", str(out)]) == 0
    data = json.loads(out.read_text())
    for rec in data["records"]:
        if rec["bench"] == "pipeline" and rec["case"] in dict(benchrun.PIPELINE_CASES):
            rec["us_per_event"] = rec["us_per_event"] / 1000.0  # impossible baseline
    out.write_text(json.dumps(data))
    RECORDS.clear()
    assert _run(["--compare", str(out), "--smoke", "--mode", "serial"]) == 1


def test_compare_gate_reports_missing_mode(tmp_path):
    out = tmp_path / "empty.json"
    out.write_text(json.dumps({"harness": "benchmarks.run", "records": []}))
    assert _run(["--compare", str(out), "--smoke", "--mode", "serial"]) == 2


def test_checked_in_baseline_has_drift_gate_records():
    """BENCH_pipeline.json must carry smoke-mode records so the drift gate
    below (and CI smoke runs) have a same-workload baseline."""
    with open(BENCH_JSON) as f:
        data = json.load(f)
    modes = {
        (r["case"], r.get("mode", "full"))
        for r in data["records"]
        if r["bench"] == "pipeline"
    }
    for name, _ in benchrun.PIPELINE_CASES:
        assert (name, "full") in modes
        assert (name, "smoke") in modes


@pytest.mark.skipif(
    os.environ.get("REPRO_SKIP_PERF_GATE", "") == "1",
    reason="perf drift gate disabled (slow/emulated machine)",
)
def test_drift_gate_against_checked_in_baseline():
    """Order-of-magnitude drift gate vs the checked-in records: the loose
    tolerance (ratio <= 4) absorbs CI noise while still catching a
    seed-era (~10x) per-event regression.  The baselines are absolute
    timings from the reference container — on machines more than ~4x
    slower, opt out with REPRO_SKIP_PERF_GATE=1."""
    status = _run(["--compare", BENCH_JSON, "--smoke", "--mode", "serial",
                   "--compare-tolerance", "3.0"])
    assert status == 0, (
        "pipeline us_per_event drifted >4x from BENCH_pipeline.json — a real "
        "regression, or a machine much slower than the reference container "
        "(set REPRO_SKIP_PERF_GATE=1 to opt out on slow/emulated machines)"
    )


@pytest.mark.slow
@pytest.mark.skipif(
    os.environ.get("REPRO_RUN_SLOW", "") != "1",
    reason="strict full-duration gate; set REPRO_RUN_SLOW=1",
)
def test_strict_full_duration_regression_gate():
    """The PR's contract: full-duration pipeline cases within 35% of the
    checked-in baseline."""
    assert _run(["--compare", BENCH_JSON, "--mode", "serial"]) == 0
