"""App compiler: spec resolution, DSL lowering, QF feedback edge, and the
bit-identity guarantee for ``ScenarioConfig`` preset apps.

The frozen summaries below were recorded at the pre-compiler commit
(42156c3, hard-wired scenario pipeline) for seed 0; the compiled preset
apps must reproduce them bit-for-bit (acceptance: the refactor changes the
API, not a single trajectory).
"""

import pytest

from repro.core.compile import (
    DeploymentSpec,
    as_detection,
    compile_app,
    linear_xi,
    resolve_module,
)
from repro.core.dataflow import ModuleSpec, TrackingApp, fc_is_active
from repro.core.events import Event, EventHeader
from repro.core.tracking import Detection
from repro.sim import AppCase, ScenarioConfig, SweepRunner, TrackingScenario

# --------------------------------------------------------------------- #
# Frozen pre-refactor summaries (seed 0; 300 cameras / 180 s, 200 for    #
# the all-active base TL)                                                #
# --------------------------------------------------------------------- #
GOLDEN = {
    "base": {
        "source_events": 36200, "on_time": 2070, "delayed": 16670,
        "dropped": 0, "delayed_frac": 0.8895, "dropped_frac": 0.0,
        "median_latency_s": 66.217, "p99_latency_s": 130.517,
        "peak_active": 200, "positives_generated": 31, "positives_completed": 14,
    },
    "bfs": {
        "source_events": 2195, "on_time": 2195, "delayed": 0, "dropped": 0,
        "delayed_frac": 0.0, "dropped_frac": 0.0, "median_latency_s": 0.157,
        "p99_latency_s": 0.517, "peak_active": 28,
        "positives_generated": 31, "positives_completed": 23,
    },
    "wbfs": {
        "source_events": 1472, "on_time": 1472, "delayed": 0, "dropped": 0,
        "delayed_frac": 0.0, "dropped_frac": 0.0, "median_latency_s": 0.157,
        "p99_latency_s": 0.397, "peak_active": 21,
        "positives_generated": 31, "positives_completed": 23,
    },
    "prob": {
        "source_events": 1242, "on_time": 1242, "delayed": 0, "dropped": 0,
        "delayed_frac": 0.0, "dropped_frac": 0.0, "median_latency_s": 0.157,
        "p99_latency_s": 0.277, "peak_active": 16,
        "positives_generated": 31, "positives_completed": 23,
    },
    # The trickier lowering paths: static/NOB batchers through the spec
    # resolution, and the avoid-drop flag plumbing with drops enabled.
    "bfs_static20": {
        "source_events": 2472, "on_time": 2098, "delayed": 282, "dropped": 0,
        "delayed_frac": 0.1185, "dropped_frac": 0.0, "median_latency_s": 6.354,
        "p99_latency_s": 33.564, "peak_active": 28,
        "positives_generated": 31, "positives_completed": 23,
    },
    "bfs_nob": {
        "source_events": 2195, "on_time": 2195, "delayed": 0, "dropped": 0,
        "delayed_frac": 0.0, "dropped_frac": 0.0, "median_latency_s": 0.157,
        "p99_latency_s": 0.517, "peak_active": 28,
        "positives_generated": 31, "positives_completed": 23,
    },
}


def _cfg(tl, **kw):
    base = dict(num_cameras=300, duration_s=180.0, seed=0, tl=tl)
    base.update(kw)
    return ScenarioConfig(**base)


@pytest.mark.parametrize("tl", ["base", "bfs", "wbfs", "prob"])
def test_preset_apps_bit_identical_to_pre_refactor(tl):
    cfg = _cfg(tl, num_cameras=200 if tl == "base" else 300,
               batching="dynamic", m_max=25)
    assert TrackingScenario(cfg).run().summary() == GOLDEN[tl]


def test_static_and_nob_batching_bit_identical():
    s20 = TrackingScenario(_cfg("bfs", batching="static", static_batch=20)).run()
    assert s20.summary() == GOLDEN["bfs_static20"]
    nob = TrackingScenario(_cfg("bfs", batching="nob")).run()
    assert nob.summary() == GOLDEN["bfs_nob"]


def test_explicit_app_equals_preset(tiny_cfg=None):
    """`TrackingScenario(cfg)` and `TrackingScenario(cfg, app=cfg.to_app(),
    deployment=cfg.deployment())` are the same program."""
    cfg = _cfg("bfs", duration_s=60.0)
    implicit = TrackingScenario(cfg).run().summary()
    sc = TrackingScenario(cfg, app=cfg.to_app(), deployment=cfg.deployment())
    assert sc.run().summary() == implicit


# --------------------------------------------------------------------- #
# ModuleSpec hygiene + spec resolution                                   #
# --------------------------------------------------------------------- #
def test_module_spec_validates_at_construction():
    with pytest.raises(ValueError):
        ModuleSpec(batching="bogus")
    with pytest.raises(ValueError):
        ModuleSpec(resource_tier="mainframe")
    with pytest.raises(ValueError):
        ModuleSpec(instances=0)
    with pytest.raises(ValueError):
        ModuleSpec(m_max=-1)
    with pytest.raises(ValueError):
        ModuleSpec(xi=3.14)


def test_module_spec_no_shared_default_xi():
    """The old `xi: Callable = lambda b: 0.0` default was one shared object
    across every spec; now None means "inherit" and is resolved per app."""
    a, b = ModuleSpec(), ModuleSpec()
    assert a.xi is None and b.xi is None
    assert a.batching is None  # inherit, not a silently-pinned 'dynamic'


def _tiny_app(**spec_kw):
    from repro.core.roadnet import make_road_network
    from repro.core.tracking import TLBase

    road = make_road_network(num_vertices=30, target_edges=84, seed=0)
    return TrackingApp(
        name="t", fc=fc_is_active, va=lambda c, f, s: [(c, x) for x in f],
        cr=lambda c, v, s: [(c, x) for x in v], tl=TLBase(road, {0: 0}),
        specs=spec_kw,
    )


def test_resolve_module_merges_app_over_deployment_over_defaults():
    app = _tiny_app(VA=ModuleSpec(instances=7, m_max=11))
    dep = DeploymentSpec(modules={
        "VA": ModuleSpec(instances=3, batching="nob", xi=linear_xi(0.1, 0.2)),
        "CR": ModuleSpec(instances=5),
    })
    va = resolve_module(app, dep, "VA")
    assert va.instances == 7          # app override wins
    assert va.m_max == 11             # app override wins
    assert va.batching == "nob"       # deployment default fills in
    assert va.xi(2) == pytest.approx(0.5)
    cr = resolve_module(app, dep, "CR")
    assert cr.instances == 5          # deployment default
    assert cr.batching == "dynamic"   # global default
    assert cr.resource_tier == "cloud"  # per-module global tier default
    fc = resolve_module(app, dep, "FC")
    assert fc.instances == 1 and fc.resource_tier == "edge"
    assert fc.xi(100) == 0.0          # no cost model anywhere -> free


def test_deployment_spec_validates():
    with pytest.raises(ValueError):
        DeploymentSpec(num_nodes=0)
    with pytest.raises(ValueError):
        DeploymentSpec(modules={"NOPE": ModuleSpec()})


def test_as_detection_coerces_bare_verdicts():
    det = Detection(camera_id=4, positive=True, timestamp=2.0)
    ev = Event(header=EventHeader(event_id=1, source_arrival=1.5), key=4, value=det)
    assert as_detection(ev) is det
    ev2 = Event(header=EventHeader(event_id=2, source_arrival=3.25), key=9, value=True)
    d2 = as_detection(ev2)
    assert d2.camera_id == 9 and d2.positive and d2.timestamp == 3.25


def test_compile_app_requires_scheduler():
    with pytest.raises(ValueError):
        compile_app(_tiny_app(), object(), DeploymentSpec(), None)


# --------------------------------------------------------------------- #
# QF feedback edge (§2.2.5)                                              #
# --------------------------------------------------------------------- #
def _qf_cfg():
    return ScenarioConfig(num_cameras=200, duration_s=60.0, seed=0, tl="bfs")


def test_qf_fused_query_reaches_va_cr_before_next_batch():
    """A query pushed by QF must be visible in VA/CR state before the next
    batch executes (one control latency after the triggering detection)."""
    cfg = _qf_cfg()
    app = cfg.to_app()
    box = {}
    va_obs = []  # (sim time, query seen) per VA batch
    qf_calls = []  # sim time of each fusion

    inner_va = app.va

    def observing_va(camera_id, frames, state):
        va_obs.append((box["sim"].time, state.get("entity_query")))
        return inner_va(camera_id, frames, state)

    def qf(detections, state):
        n = state.get("fused", 0) + len(detections)
        state["fused"] = n
        qf_calls.append(box["sim"].time)
        return ("q", n)

    app.va = observing_va
    app.qf = qf
    sc = TrackingScenario(cfg, app=app, deployment=cfg.deployment())
    box["sim"] = sc.sim
    sc.run()

    assert qf_calls, "the entity was sighted; QF must have fused queries"
    assert sc.compiled.query_pushes == len(qf_calls)
    fused = sc.compiled.qf_state["entity_query"]
    assert fused == ("q", sc.compiled.qf_state["fused"])
    # The push propagated to every VA and CR instance's state.
    for t in sc.compiled.va_tasks + sc.compiled.cr_tasks:
        assert t.state["entity_query"] == fused
    # Every batch executing after the first push's control latency saw a
    # fused (non-None) query — i.e. the update landed before the next batch.
    latency = sc.sim.network.man_latency_s
    horizon = qf_calls[0] + latency
    late = [(t, q) for t, q in va_obs if t > horizon]
    assert late, "batches kept executing after the first fusion"
    assert all(q is not None for _, q in late)


def test_qf_none_and_noop_qf_do_not_change_trajectories():
    """Apps without QF are untouched by the new edge, and a QF that never
    fuses (returns None) is observationally identical to no QF."""
    cfg = _qf_cfg()
    base = TrackingScenario(cfg).run()
    assert base.query_pushes == 0

    app = cfg.to_app()
    app.qf = lambda detections, state: None
    noop = TrackingScenario(cfg, app=app, deployment=cfg.deployment()).run()
    assert noop.query_pushes == 0
    assert noop.summary() == base.summary()


# --------------------------------------------------------------------- #
# (app, deployment) grids through the sweep engine                       #
# --------------------------------------------------------------------- #
def _factory(tl_name):
    def make(world, cameras):
        cfg = ScenarioConfig(tl=tl_name)
        app = cfg.to_app(world, cameras)
        app.name = f"grid-{tl_name}"
        return app

    return make


@pytest.mark.skipif(not SweepRunner.fork_available(), reason="fork unavailable")
# Forcing fork after another test initialized JAX in this process trips
# JAX's os.fork() RuntimeWarning; these workers never touch JAX (preset
# apps, embed_dim=0), which is exactly the fork-safe pattern sweep.py
# documents — silence the advisory rather than degrade the test to serial.
@pytest.mark.filterwarnings("ignore:os\\.fork\\(\\) was called:RuntimeWarning")
def test_app_grid_fork_matches_serial():
    wl = ScenarioConfig(num_cameras=200, duration_s=45.0, seed=0)
    grid = [
        (tl, AppCase(app=_factory(tl), workload=wl, deployment=DeploymentSpec()))
        for tl in ("bfs", "wbfs")
    ]
    serial = SweepRunner(mode="serial").run(grid)
    fork = SweepRunner(mode="fork").run(grid)
    assert fork.mode == "fork"
    for a, b in zip(serial.records, fork.records):
        assert a.summary == b.summary
        assert a.summary["source_events"] > 0


def test_app_case_matches_equivalent_config_case():
    """An AppCase built from `to_app()` reproduces the plain-config case
    bit-identically through the sweep engine."""
    cfg = ScenarioConfig(num_cameras=200, duration_s=45.0, seed=0, tl="wbfs")
    res = SweepRunner(mode="serial").run([
        ("cfg", cfg),
        ("app", AppCase(
            app=lambda world, cameras: cfg.to_app(world, cameras),
            workload=cfg,
            deployment=cfg.deployment(),
        )),
    ])
    assert res.records[0].summary == res.records[1].summary


def test_avoid_drop_shields_bare_bool_verdicts():
    """make_cr apps emit bare bool verdicts; avoid_drop_positives must
    shield those exactly like Detection.positive ones (same interpretation
    as_detection applies at the sink)."""
    from repro.core.compile import _adapt_cr

    logic = _adapt_cr(lambda c, v, s: [(c, bool(getattr(x, "has_entity", False))) for x in v], True)

    class _Frame:
        has_entity = True

    hit = Event(header=EventHeader(event_id=1, source_arrival=0.0), key=2, value=_Frame())
    miss = Event(header=EventHeader(event_id=2, source_arrival=0.0), key=2, value=object())
    out = logic([hit, miss], {})
    assert [ev.value for ev in out] == [True, False]
    assert out[0].header.avoid_drop and not out[1].header.avoid_drop


def test_seed_tl_keeps_preseeded_app_state():
    """An app whose TL arrives warm-started (last_seen + active set) keeps
    that state; fresh TLs are pointed at the query's last-seen location."""
    cfg = ScenarioConfig(num_cameras=150, duration_s=20.0, seed=3, tl="bfs")
    app = cfg.to_app()
    app.tl.last_seen_camera = 42
    app.tl.last_seen_time = 5.0
    app.tl.active = {42, 43, 44}
    sc = TrackingScenario(cfg, app=app, deployment=cfg.deployment())
    assert sc.tl.last_seen_camera == 42 and sc.tl.last_seen_time == 5.0
    assert sc.tl.active == {42, 43, 44}
    assert sc.compiled.fc_active == {42, 43, 44}
    fresh = TrackingScenario(cfg)
    assert fresh.tl.last_seen_time == 0.0
    assert fresh.tl.active == fresh.tl.spotlight(0.0)


def test_apply_keyed_none_filters_keep_attribution():
    """Filtering via None pairs keeps survivor payloads married to their
    own events (a compacted shorter list would misattribute them)."""
    from repro.core.compile import _apply_keyed

    def va(camera_id, frames, state):
        return [(camera_id, f * 10) if f % 2 else None for f in frames]

    events = [
        Event(header=EventHeader(event_id=i, source_arrival=float(i)), key=7, value=i)
        for i in (1, 2, 3)
    ]
    out = _apply_keyed(va, events, {})
    assert [(ev.header.event_id, ev.value) for ev in out] == [(1, 10), (3, 30)]
