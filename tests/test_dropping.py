"""Drop points (§4.3) + bounds behaviours (§4.6).

The hypothesis-based skew-invariance and stability properties live in
``test_dropping_props.py`` (skipped when the optional ``hypothesis`` test
dependency is missing; see pyproject.toml ``[project.optional-dependencies]``).
"""

import math

import pytest

from repro.core.bounds import (
    batching_latency_overhead,
    drop_rate,
    max_sustainable_rate,
    stable_batch_size,
)
from repro.core.dropping import (
    drop_before_exec,
    drop_before_queuing,
    drop_before_transmit,
)
from repro.core.events import Event, EventHeader


def xi(b):
    return 0.05 + 0.01 * b


def ev(eid=0, a1=0.0, avoid=False):
    return Event(header=EventHeader(event_id=eid, source_arrival=a1, avoid_drop=avoid), key=eid)


class TestDropPoints:
    def test_dp1_basic(self):
        # u + xi(1) = 1.0 + 0.06 > beta=1.0 -> drop
        assert drop_before_queuing(0.0, 1.0, xi(1), 1.0)
        assert not drop_before_queuing(0.0, 0.5, xi(1), 1.0)

    def test_dp1_avoid_drop(self):
        assert not drop_before_queuing(0.0, 99.0, xi(1), 1.0, avoid_drop=True)

    def test_dp2_partitions_batch(self):
        batch = [
            (0.0, 0.1, 0.05, ev(0)),   # u=0.1 q=0.05 + xi(3)=0.08 -> 0.23 <= 0.5 keep
            (0.0, 0.45, 0.05, ev(1)),  # 0.58 > 0.5 drop
            (0.0, 0.45, 0.05, ev(2, avoid=True)),  # protected
        ]
        retained, dropped = drop_before_exec(batch, xi(3), 0.5)
        assert [e.event_id for e in retained] == [0, 2]
        assert [e.event_id for e in dropped] == [1]

    def test_dp3(self):
        assert drop_before_transmit(0.0, 0.4, 0.2, 0.5)   # 0.6 > 0.5
        assert not drop_before_transmit(0.0, 0.2, 0.2, 0.5)
        assert not drop_before_transmit(0.0, 9.0, 9.0, 0.5, avoid_drop=True)


class TestBounds:
    def test_stable_batch_size_grows_with_headroom(self):
        m1 = stable_batch_size(xi, omega=20.0, budget_headroom=0.5)
        m2 = stable_batch_size(xi, omega=20.0, budget_headroom=2.0)
        assert m1 is None or m2 is None or m2 >= m1

    def test_unsustainable_rate_returns_none(self):
        # xi(1)=0.06 => max streaming rate ~16/s; per-batch service tops out
        # near 1/0.01=100/s; 10_000/s is unsustainable for headroom 0.3.
        assert stable_batch_size(xi, omega=10_000.0, budget_headroom=0.3) is None

    def test_drop_rate_zero_when_sustainable(self):
        d, omax, m = drop_rate(xi, omega=5.0, budget_headroom=2.0)
        assert d == 0.0 and m >= 1

    def test_drop_rate_positive_when_overloaded(self):
        d, omax, m = drop_rate(xi, omega=10_000.0, budget_headroom=0.3)
        assert d > 0 and omax < 10_000.0

    def test_batching_latency_overhead_positive(self):
        assert batching_latency_overhead(xi, omega=10.0, m=8) > 0
        assert batching_latency_overhead(xi, omega=10.0, m=1) == pytest.approx(0.0)
