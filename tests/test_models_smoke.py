"""Per-architecture smoke tests (deliverable f): a REDUCED variant of each
family (2 layers, d_model<=512, <=4 experts) runs one forward and one train
step on CPU; output shapes + finiteness asserted.  The FULL configs are
exercised only via the dry-run (ShapeDtypeStructs, no allocation)."""

import jax
import jax.numpy as jnp
import pytest

from repro.config import get_config, list_configs
from repro.configs import ASSIGNED_ARCHS
from repro.models import forward, init_params, reduced_config
from repro.training import AdamWConfig, TrainConfig, init_adamw, lm_batches, make_train_step

KEY = jax.random.PRNGKey(0)
B, S = 2, 32


def make_batch(cfg, with_labels=True):
    k = jax.random.fold_in(KEY, 1)
    batch = {}
    if cfg.arch_type == "encdec":
        batch["frames"] = jax.random.normal(k, (B, cfg.encoder_seq, cfg.d_model)) * 0.02
        batch["tokens"] = jax.random.randint(k, (B, S), 0, cfg.vocab_size)
    elif cfg.frontend_stub:
        batch["embeds"] = jax.random.normal(k, (B, S, cfg.d_model)) * 0.02
    else:
        batch["tokens"] = jax.random.randint(k, (B, S), 0, cfg.vocab_size)
    if with_labels:
        batch["labels"] = jax.random.randint(k, (B, S), 0, cfg.vocab_size)
    return batch


def test_all_assigned_archs_registered():
    assert set(ASSIGNED_ARCHS) == set(list_configs())
    assert len(ASSIGNED_ARCHS) == 10


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_reduced_constraints(arch):
    cfg = reduced_config(get_config(arch))
    assert cfg.n_layers == 2
    assert cfg.d_model <= 512
    if cfg.moe.enabled:
        assert cfg.moe.num_experts <= 4


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = reduced_config(get_config(arch))
    params = init_params(KEY, cfg)
    logits, aux = forward(params, cfg, make_batch(cfg, with_labels=False))
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits[..., : cfg.vocab_size])))
    assert bool(jnp.isfinite(aux["lb_loss"])) and bool(jnp.isfinite(aux["z_loss"]))


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_one_train_step(arch):
    cfg = reduced_config(get_config(arch))
    params = init_params(KEY, cfg)
    opt = init_adamw(params)
    tcfg = TrainConfig(adamw=AdamWConfig(lr=1e-4), warmup_steps=1, total_steps=10)
    step = jax.jit(make_train_step(cfg, tcfg))
    new_params, new_opt, metrics = step(params, opt, make_batch(cfg))
    assert bool(jnp.isfinite(metrics["loss"]))
    assert int(new_opt.step) == 1
    # params actually moved
    moved = jax.tree.reduce(
        lambda acc, pq: acc or bool(jnp.any(pq)),
        jax.tree.map(lambda a, b: jnp.any(a != b), params, new_params),
        False,
    )
    assert moved
