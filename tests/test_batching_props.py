"""Clock-skew resilience properties for the batchers (§4.6.2).

Requires the optional ``hypothesis`` test dependency (declared in
pyproject.toml under ``[project.optional-dependencies] test``); the module
is skipped cleanly when it is not installed.
"""

import pytest

hypothesis = pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st

from repro.core.batching import DynamicBatcher, PendingEvent
from repro.core.events import Event, EventHeader


def xi(b):
    return 0.05 + 0.01 * b


def pe(eid, arrival, deadline):
    ev = Event(header=EventHeader(event_id=eid, source_arrival=arrival), key=eid)
    return PendingEvent(event=ev, arrival=arrival, deadline=deadline)


# ----------------------------------------------------------------------- #
# Clock-skew resilience (§4.6.2): adding a constant skew sigma to the     #
# local clock shifts arrivals, now, and (learned) deadlines equally, so    #
# the admit decision is unchanged.                                         #
# ----------------------------------------------------------------------- #
@settings(max_examples=200, deadline=None)
@given(
    sigma=st.floats(-50, 50, allow_nan=False),
    arrivals=st.lists(st.floats(0, 10), min_size=2, max_size=8),
    beta=st.floats(0.1, 5.0),
)
def test_dynamic_batcher_skew_invariance(sigma, arrivals, beta):
    arrivals = sorted(arrivals)

    def run(skew: float):
        b = DynamicBatcher(xi, m_max=25)
        decisions = []
        for i, a in enumerate(arrivals):
            # deadline = a_1 + beta measured on the skewed clock: both the
            # event deadline and 'now' carry the same +skew.
            out = b.offer(pe(i, a + skew, a + skew + beta), a + skew)
            decisions.append(0 if out is None else len(out))
        return decisions

    assert run(0.0) == run(sigma)


@settings(max_examples=100, deadline=None)
@given(
    deadlines=st.lists(st.floats(1.0, 20.0), min_size=1, max_size=10),
)
def test_batch_deadline_is_min_of_event_deadlines(deadlines):
    b = DynamicBatcher(xi, m_max=100)
    for i, d in enumerate(deadlines):
        b.offer(pe(i, 0.0, d), 0.0)
    if b.current_size == len(deadlines):  # no intermediate flush happened
        assert b.next_due_time() == pytest.approx(
            min(deadlines) - xi(len(deadlines))
        )
