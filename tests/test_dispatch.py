"""Bucket-batched kernel dispatch: padding must be invisible in results,
operands must stay device-resident, and an entire sweep of varying batch
sizes must compile each kernel at most once per bucket shape."""

import numpy as np
import pytest

pytest.importorskip("jax")

from repro.core.roadnet import make_road_network
from repro.kernels import dispatch
from repro.kernels.reid_match.ref import reid_match_ref
from repro.kernels.spotlight_ball.ops import spotlight_ball as ops_spotlight_ball


@pytest.fixture(scope="module")
def road():
    return make_road_network(num_vertices=180, target_edges=500, seed=17)


def test_bucket_rounding():
    assert dispatch.bucket(1) == dispatch.BUCKET_MIN
    assert dispatch.bucket(8) == 8
    assert dispatch.bucket(9) == 16
    assert dispatch.bucket(16) == 16
    assert dispatch.bucket(17) == 32
    assert dispatch.bucket(3, minimum=1) == 4
    with pytest.raises(ValueError):
        dispatch.bucket(0)


def test_spotlight_ball_padding_is_invisible(road):
    indptr, indices, weights = road.csr()
    rng = np.random.default_rng(2)
    for Q in (1, 3, 8, 9, 13):
        sources = rng.integers(0, road.num_vertices, Q).astype(np.int32)
        radii = rng.uniform(50.0, 2500.0, Q).astype(np.float32)
        got = np.asarray(dispatch.spotlight_ball(indptr, indices, weights, sources, radii))
        want = np.asarray(
            ops_spotlight_ball(indptr, indices, weights.astype(np.float32), sources, radii)
        )
        assert got.shape == (Q, road.num_vertices)
        np.testing.assert_array_equal(got, want)


def test_reid_match_padding_matches_up_to_ulp():
    # Padding the gallery changes the GEMM blocking, so scores may differ
    # from the unpadded call in the last ulp (deterministically per shape);
    # matches must agree everywhere the score isn't within an ulp of the
    # threshold.
    rng = np.random.default_rng(3)
    threshold = 0.3
    for D in (16, 32):
        queries = rng.normal(size=(3, D)).astype(np.float32)
        for N in (1, 2, 8, 11, 40):
            gallery = rng.normal(size=(N, D)).astype(np.float32)
            got_s, got_b, got_m = [
                np.asarray(x) for x in dispatch.reid_match(gallery, queries, threshold=threshold)
            ]
            ref_s, ref_b, ref_m = [
                np.asarray(x) for x in reid_match_ref(gallery, queries, threshold=threshold)
            ]
            assert got_s.shape == ref_s.shape
            np.testing.assert_allclose(got_s, ref_s, rtol=2e-6, atol=2e-7)
            clear = np.abs(ref_s - threshold) > 1e-5
            np.testing.assert_array_equal(got_m[clear], ref_m[clear])
            # Self-consistency: is_match is exactly scores >= threshold.
            np.testing.assert_array_equal(
                got_m, got_s >= np.float32(threshold)
            )


def test_reid_negative_scores_not_clobbered_by_padding():
    # All-negative similarities: a zero pad query would win the max if the
    # mask were missing.
    gallery = np.array(
        [[1, 1, 0, 0], [1, 2, 0, 0], [2, 1, 0, 0]], dtype=np.float32
    )
    queries = -np.eye(4, dtype=np.float32)[:2]
    scores, _, matched = [np.asarray(x) for x in dispatch.reid_match(gallery, queries)]
    ref_scores, _, ref_matched = [
        np.asarray(x) for x in reid_match_ref(gallery, queries)
    ]
    np.testing.assert_allclose(scores, ref_scores, rtol=2e-6, atol=2e-7)
    assert (scores < 0).all() and not matched.any()
    np.testing.assert_array_equal(matched, ref_matched)


def test_dense_adjacency_cached_per_network(road):
    indptr, indices, weights = road.csr()
    src = np.zeros(2, np.int32)
    rad = np.full(2, 100.0, np.float32)
    dispatch.spotlight_ball(indptr, indices, weights, src, rad)
    before = dispatch.stats()
    dispatch.spotlight_ball(indptr, indices, weights, src, rad)
    after = dispatch.stats()
    assert after["device_cache_hits"] > before["device_cache_hits"]
    assert after["device_cache_misses"] == before["device_cache_misses"]


def test_at_most_one_compile_per_bucket_shape():
    """Acceptance: across a whole sweep of varying batch sizes, the padded
    kernels recompile at most once per bucket shape (jit cache-miss count
    == distinct bucket shapes dispatched).  Uses a private network so cache
    state from other tests cannot mask compilations."""
    net = make_road_network(num_vertices=150, target_edges=420, seed=23)
    indptr, indices, weights = net.csr()
    rng = np.random.default_rng(4)

    # Warm both kernels once so module-level compilation state exists.
    # D=24 is private to this test: other tests must not pre-compile the
    # reid shapes whose cache misses are being counted.
    D = 24
    dispatch.spotlight_ball(indptr, indices, weights,
                            np.zeros(1, np.int32), np.full(1, 10.0, np.float32))
    dispatch.reid_match(rng.normal(size=(2, D)).astype(np.float32),
                        rng.normal(size=(1, D)).astype(np.float32))
    base = dispatch.jit_cache_sizes()

    # A "sweep" of calls: many batch sizes, only two buckets each (8, 16).
    for Q in (1, 2, 3, 5, 8, 9, 12, 16, 7, 11):
        sources = rng.integers(0, net.num_vertices, Q).astype(np.int32)
        radii = rng.uniform(10.0, 500.0, Q).astype(np.float32)
        dispatch.spotlight_ball(indptr, indices, weights, sources, radii)
    for N in (1, 4, 8, 9, 16, 3, 13):
        dispatch.reid_match(rng.normal(size=(N, D)).astype(np.float32),
                            rng.normal(size=(1, D)).astype(np.float32))

    sizes = dispatch.jit_cache_sizes()
    # Q in 1..8 -> bucket 8 (already warm), 9..16 -> bucket 16: exactly one
    # new compile per kernel despite 10 (7) distinct batch sizes.
    assert sizes["ball"] - base["ball"] == 1
    assert sizes["reid"] - base["reid"] == 1

    # Re-running the same sweep adds no compiles at all.
    for Q in (2, 9, 16, 5):
        sources = rng.integers(0, net.num_vertices, Q).astype(np.int32)
        radii = rng.uniform(10.0, 500.0, Q).astype(np.float32)
        dispatch.spotlight_ball(indptr, indices, weights, sources, radii)
    assert dispatch.jit_cache_sizes()["ball"] == sizes["ball"]


def test_spotlight_multi_kernel_path_uses_dispatch(road):
    from repro.core.tracking import TLProbabilistic

    cams = {c: c for c in range(road.num_vertices)}
    tl = TLProbabilistic(road, cams, entity_speed=4.0, coverage=0.9)
    for i, cam in enumerate((3, 40, 99)):
        tl.track(f"e{i}", cam, float(i))
    before = dispatch.stats()["ball_calls"]
    py = tl.spotlight_multi(25.0)
    kr = tl.spotlight_multi(25.0, use_kernel=True)
    assert py == kr and py
    assert dispatch.stats()["ball_calls"] == before + 1


def test_scenario_reid_path_counts_matches():
    """embed_dim > 0 routes VA batches through the bucketed re-id matcher;
    entity frames embed near the entity embedding, so matches track the
    generated positives."""
    from repro.sim import ScenarioConfig, TrackingScenario

    cfg = ScenarioConfig(
        num_cameras=60, road_vertices=150, duration_s=30.0, seed=61,
        embed_dim=16, tl="base", batching="static", static_batch=10,
    )
    res = TrackingScenario(cfg).run()
    assert res.positives_generated > 0
    assert res.reid_matched > 0
    # The matcher sees every frame exactly once; true matches cannot exceed
    # total frames and should be in the neighbourhood of the positives.
    assert res.reid_matched <= res.source_events
    # Disabled path records nothing.
    cfg0 = ScenarioConfig(
        num_cameras=60, road_vertices=150, duration_s=30.0, seed=61,
        tl="base", batching="static", static_batch=10,
    )
    assert TrackingScenario(cfg0).run().reid_matched == 0


def test_reid_multi_buckets_and_compile_accounting():
    """The query-major kernel obeys the same dispatch contracts as the
    single-query one: power-of-two bucket padding on BOTH axes, call/shape
    stats, and at most one jit compile per bucket shape."""
    rng = np.random.default_rng(9)
    D = 40  # private to this test, like the single-query compile test
    before = dispatch.stats()["reid_multi_calls"]
    dispatch.reid_match_multi(rng.normal(size=(2, D)).astype(np.float32),
                              rng.normal(size=(1, D)).astype(np.float32))
    base = dispatch.jit_cache_sizes()["reid_multi"]
    # Gallery 1..8 and queries 1..8 share one (8, 8, D) bucket shape.
    for N, Q in ((1, 1), (3, 2), (8, 8), (5, 7)):
        g = rng.normal(size=(N, D)).astype(np.float32)
        q = rng.normal(size=(Q, D)).astype(np.float32)
        scores, matched = dispatch.reid_match_multi(g, q)
        assert np.asarray(scores).shape == (N, Q)
        assert np.asarray(matched).shape == (N, Q)
    assert dispatch.jit_cache_sizes()["reid_multi"] == base
    # A new bucket (Q > 8) costs exactly one more compile.
    dispatch.reid_match_multi(rng.normal(size=(2, D)).astype(np.float32),
                              rng.normal(size=(9, D)).astype(np.float32))
    assert dispatch.jit_cache_sizes()["reid_multi"] == base + 1
    assert dispatch.stats()["reid_multi_calls"] == before + 6


def test_jit_cache_is_bounded(monkeypatch):
    """Sweeping more distinct bucket shapes than MAX_JIT_SHAPES must not
    grow a kernel's compile cache without bound: the LRU drops the cache on
    overflow and rebuilds it for the working set."""
    monkeypatch.setattr(dispatch, "MAX_JIT_SHAPES", 4)
    rng = np.random.default_rng(6)
    # Each feature width D is its own bucket shape for the reid kernel.
    for D in (52, 56, 60, 64, 68, 72, 76):
        dispatch.reid_match(rng.normal(size=(2, D)).astype(np.float32),
                            rng.normal(size=(1, D)).astype(np.float32))
        assert dispatch.jit_cache_sizes()["reid"] <= 4
        assert len(dispatch._JIT_LRU["reid"]) <= 4
    # A shape inside the live working set does not recompile.
    size = dispatch.jit_cache_sizes()["reid"]
    dispatch.reid_match(rng.normal(size=(2, 76)).astype(np.float32),
                        rng.normal(size=(1, 76)).astype(np.float32))
    assert dispatch.jit_cache_sizes()["reid"] == size
