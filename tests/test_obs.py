"""Unified observability plane (PR 10): metrics registry, span tracing,
exporters, health probes — and the determinism acceptance gates.

The hard contract under test: SIM-domain metric values (and the Prometheus
exposition built from them) are **bit-identical** across (a) an
uninterrupted seed-0 run, (b) a driver killed mid-run and restored from
its journal, and (c) 1/2/8-way camera-mesh sharded runs.  WALL-domain
metrics (engine attribution, kernel profiling, serving counters) are
exported but never digested.
"""

import copy
import json
import math

import pytest

from repro.obs import (
    SIM,
    WALL,
    EventTracer,
    MetricsRegistry,
    Span,
    exposition_digest,
    healthz,
    metrics_jsonl,
    probe_backend,
    probe_journal,
    probe_stage,
    prometheus_exposition,
    readyz,
    spans_jsonl,
    transit_class,
)
from repro.sim import ScenarioConfig, TrackingScenario


# --------------------------------------------------------------------- #
# Registry semantics                                                     #
# --------------------------------------------------------------------- #
class TestRegistry:
    def test_counter_gauge_histogram_basics(self):
        reg = MetricsRegistry()
        c = reg.counter("repro_events_total", "Events.", labels=("task",))
        c.inc(task="VA")
        c.inc(2, task="VA")
        c.inc(task="CR")
        assert c.value(task="VA") == 3 and c.value(task="CR") == 1
        g = reg.gauge("repro_queue_depth", "Queue depth.")
        g.set(7)
        g.inc(-2)
        assert g.value() == 5
        h = reg.histogram("repro_latency_seconds", "Latency.",
                          buckets=(0.1, 1.0))
        for v in (0.05, 0.5, 5.0):
            h.observe(v)
        assert h.count() == 3

    def test_name_help_and_label_contracts(self):
        reg = MetricsRegistry()
        with pytest.raises(ValueError):
            reg.counter("events_total", "missing repro_ prefix")
        with pytest.raises(ValueError):
            reg.counter("repro_Bad", "uppercase")
        with pytest.raises(ValueError):
            reg.counter("repro_ok", "")
        with pytest.raises(ValueError):
            reg.counter("repro_ok", "help", labels=("Bad-Label",))
        c = reg.counter("repro_ok", "help", labels=("task",))
        with pytest.raises(ValueError):
            c.inc(task="VA", extra="nope")  # label set must match exactly
        with pytest.raises(ValueError):
            c.inc()  # missing label
        with pytest.raises(ValueError):
            c.inc(-1, task="VA")  # counters are monotone

    def test_reregistration_idempotent_or_hard_error(self):
        reg = MetricsRegistry()
        a = reg.counter("repro_x_total", "Help.", labels=("k",))
        b = reg.counter("repro_x_total", "Help.", labels=("k",))
        assert a is b  # identical signature: same object, values survive
        with pytest.raises(ValueError):
            reg.counter("repro_x_total", "Different help.", labels=("k",))
        with pytest.raises(ValueError):
            reg.gauge("repro_x_total", "Help.", labels=("k",))

    def test_exposition_format_and_value_formatting(self):
        reg = MetricsRegistry()
        c = reg.counter("repro_n_total", "Counted things.", labels=("kind",))
        c.inc(3, kind="a")
        g = reg.gauge("repro_level", "A level.")
        g.set(0.25)
        text = prometheus_exposition(reg)
        assert "# HELP repro_n_total Counted things." in text
        assert "# TYPE repro_n_total counter" in text
        assert 'repro_n_total{kind="a"} 3' in text  # ints render bare
        assert "repro_level 0.25" in text
        ginf = reg.gauge("repro_edge", "Edge values.")
        ginf.set(math.inf)
        assert "repro_edge +Inf" in prometheus_exposition(reg)

    def test_histogram_exposition_is_cumulative(self):
        reg = MetricsRegistry()
        h = reg.histogram("repro_lat_seconds", "Lat.", buckets=(0.1, 1.0))
        for v in (0.05, 0.5, 5.0):
            h.observe(v)
        text = prometheus_exposition(reg)
        assert 'repro_lat_seconds_bucket{le="0.1"} 1' in text
        assert 'repro_lat_seconds_bucket{le="1"} 2' in text
        assert 'repro_lat_seconds_bucket{le="+Inf"} 3' in text
        assert "repro_lat_seconds_count 3" in text

    def test_digest_covers_sim_domain_only(self):
        reg = MetricsRegistry()
        reg.counter("repro_sim_total", "Sim.", domain=SIM).inc(5)
        d0 = reg.digest()
        w = reg.gauge("repro_wall_seconds", "Wall.", domain=WALL)
        w.set(123.456)
        assert reg.digest() == d0  # wall values never move the digest
        w.set(999.0)
        assert reg.digest() == d0
        reg.counter("repro_sim_total", "Sim.", domain=SIM).inc(1)
        assert reg.digest() != d0
        assert "repro_wall_seconds" not in reg.exposition(include_wall=False)
        assert "repro_wall_seconds" in reg.exposition(include_wall=True)
        assert exposition_digest(reg) == reg.digest()

    def test_metrics_jsonl_is_sorted_and_parseable(self):
        reg = MetricsRegistry()
        reg.counter("repro_b_total", "B.", labels=("k",)).inc(k="x")
        reg.counter("repro_a_total", "A.").inc(2)
        lines = metrics_jsonl(reg).strip().splitlines()
        rows = [json.loads(ln) for ln in lines]
        assert [r["name"] for r in rows] == ["repro_a_total", "repro_b_total"]
        assert rows[1]["data_points"][0]["attributes"] == {"k": "x"}


# --------------------------------------------------------------------- #
# Span tracing: hook-level semantics (stub tasks), then the pipeline     #
# --------------------------------------------------------------------- #
class _StubTask:
    def __init__(self, name, module, node):
        self.name, self.module, self.node = name, module, node


class _StubHeader:
    def __init__(self, event_id, is_probe=False):
        self.event_id, self.is_probe = event_id, is_probe


def test_transit_class():
    assert transit_class("node0", "node0") == "ipc"
    assert transit_class("node0", "node1") == "lan"
    assert transit_class("edge3", "node1") == "man"
    assert transit_class("head", "edge0") == "man"


class TestTracerHooks:
    def test_span_lifecycle_drop_and_retry(self):
        tr = EventTracer(stride=1)
        va = _StubTask("VA-0", "VA", "node0")
        cr = _StubTask("CR-0", "CR", "node1")
        h = _StubHeader(10)
        tr.on_arrival(va, h, 1.0)
        tr.on_retry(cr, h, 1.5, attempt=0)
        tr.on_arrival(cr, h, 2.0)
        tr.on_drop(cr, h, 2.5, point=2, epsilon=0.1)
        (span,) = tr.all_spans()
        assert span.status == "dropped"
        assert [hp["transit"] for hp in span.hops] == ["source", "lan"]
        assert [e["kind"] for e in span.events] == ["retry", "drop"]
        assert span.events[-1]["point"] == 2
        assert tr.drops_seen == 1 and tr.retries_seen == 1

    def test_sampling_stride_is_base_relative(self):
        tr = EventTracer(stride=4)
        t = _StubTask("VA-0", "VA", "node0")
        # Base id 1000: 1000, 1004, ... are sampled regardless of offset.
        for eid in range(1000, 1010):
            tr.on_arrival(t, _StubHeader(eid), 0.0)
        assert sorted(s.event_id for s in tr.all_spans()) == [1000, 1004, 1008]

    def test_max_spans_overflow_is_counted(self):
        tr = EventTracer(stride=1, max_spans=2)
        t = _StubTask("VA-0", "VA", "node0")
        for eid in range(5):
            tr.on_arrival(t, _StubHeader(eid), 0.0)
        assert tr.spans_started == 2 and tr.spans_overflowed == 3

    def test_to_rows_relative_ids_and_jsonl(self):
        tr = EventTracer(stride=2)
        t = _StubTask("UV", "UV", "head")
        for eid in (500, 502):
            h = _StubHeader(eid)
            tr.on_arrival(t, h, 1.0)
            tr.on_sink(t, h, 1.0, latency=0.1)
        rows = tr.to_rows()
        assert [r["event_id"] for r in rows] == [0, 2]
        parsed = [json.loads(ln) for ln in
                  spans_jsonl(tr.all_spans()).strip().splitlines()]
        assert all(p["status"] == "completed" for p in parsed)

    def test_publish_metrics_registers_sim_counters(self):
        tr = EventTracer(stride=1)
        t = _StubTask("VA-0", "VA", "node0")
        h = _StubHeader(0)
        tr.on_arrival(t, h, 0.0)
        tr.on_sink(t, h, 1.0, latency=1.0)
        reg = MetricsRegistry()
        tr.publish_metrics(reg)
        assert reg.get("repro_trace_spans_total").value(status="completed") == 1
        assert reg.get("repro_trace_hops_total").value(transit="source") == 1
        assert reg.get("repro_trace_spans_total").domain == SIM


class TestTracedPipeline:
    def test_spans_cover_va_cr_uv_with_transit_attribution(self):
        tr = EventTracer(stride=4)
        cfg = ScenarioConfig(num_cameras=20, duration_s=20.0, seed=0,
                             tracer=tr)
        TrackingScenario(cfg).run()
        done = [s for s in tr.all_spans() if s.status == "completed"]
        assert done, "no completed spans sampled"
        for s in done:
            mods = [h["module"] for h in s.hops]
            assert mods[-1] == "UV" and "VA" in mods and "CR" in mods
            assert s.hops[0]["transit"] == "source"
            assert all(h["transit"] in ("source", "ipc", "lan", "man")
                       for h in s.hops)
            assert s.latency is not None and s.latency > 0

    def test_tracer_does_not_perturb_the_run(self):
        def run(tracer):
            cfg = ScenarioConfig(num_cameras=20, duration_s=20.0, seed=0,
                                 tracer=tracer)
            return TrackingScenario(cfg).run()

        a, b = run(None), run(EventTracer(stride=4))
        assert a.latencies == b.latencies
        assert a.source_events == b.source_events
        assert a.drops_by_task == b.drops_by_task

    def test_fault_plane_annotations_reach_spans(self):
        """A host crash surfaces as retry events and DP_FAULT drop
        causality on the sampled spans."""
        from repro.core.pipeline import DP_FAULT
        from repro.sim.dynamism import DynamismSpec, HostCrash

        tr = EventTracer(stride=1, max_spans=4096)
        cfg = ScenarioConfig(
            num_cameras=60, duration_s=60.0, seed=0,
            dynamism=DynamismSpec(perturbations=(
                HostCrash(hosts=("node0",), t_start=20.0, outage_s=10.0),)),
            tracer=tr,
        )
        TrackingScenario(cfg).run()
        dropped = [s for s in tr.all_spans() if s.status == "dropped"]
        assert dropped, "crash produced no dropped spans"
        drop_events = [e for s in dropped for e in s.events
                       if e["kind"] == "drop"]
        assert all(e["point"] == DP_FAULT for e in drop_events)
        assert any(e["kind"] == "retry" for s in tr.all_spans()
                   for e in s.events)


# --------------------------------------------------------------------- #
# Health / readiness probes                                              #
# --------------------------------------------------------------------- #
class _StubStage:
    def __init__(self, arrived, dropped, xi=object()):
        self.stats = {"arrived": arrived, "dropped": dropped}
        self.xi = xi


class TestHealth:
    def test_probe_stage_drop_fraction(self):
        assert probe_stage(_StubStage(100, 10))[1] is True
        assert probe_stage(_StubStage(100, 80))[1] is False
        assert probe_stage(_StubStage(0, 0))[1] is True  # idle

    def test_probe_journal_staleness(self):
        from repro.serving.journal import Journal

        j = Journal(30.0)
        assert probe_journal(j, t_now=10.0)[1] is True  # pre-first-snapshot
        j.snapshots.append({"time": 90.0})
        assert probe_journal(j, t_now=100.0)[1] is True
        assert probe_journal(j, t_now=200.0)[1] is False  # > 2 periods stale
        assert probe_journal(None)[1] is False

    def test_probe_backend_clean(self):
        name, ok, detail = probe_backend()
        assert name == "backend" and ok, detail

    def test_healthz_readyz_aggregate(self):
        from repro.serving.journal import Journal

        rep = healthz(stage=_StubStage(10, 0), journal=Journal(30.0))
        assert rep["ok"] is True
        assert set(rep["components"]) == {"stage", "journal", "backend"}
        assert readyz(stage=_StubStage(1, 0))["ok"] is True
        assert readyz(stage=_StubStage(1, 0, xi=None))["ok"] is False


# --------------------------------------------------------------------- #
# Determinism acceptance gates                                           #
# --------------------------------------------------------------------- #
#: Frozen SIM-domain digest of the seed-0 golden below (num_cameras=20,
#: duration_s=20.0, tracer stride 4).  Bit-stable across processes, device
#: counts and in-process event-id offsets; recompute only when the metric
#: catalog or the golden workload deliberately changes.
GOLDEN_SIM_DIGEST = (
    "e6204196f344f033425c9b5c80ed95ad59adb77ec60be2d42aa4ff87a0b0f62a"
)


def _golden_registry():
    reg = MetricsRegistry()
    tracer = EventTracer(stride=4)
    cfg = ScenarioConfig(num_cameras=20, duration_s=20.0, seed=0,
                         tracer=tracer)
    scn = TrackingScenario(cfg)
    res = scn.run()
    scn.publish_metrics(reg, res)
    return reg


def test_golden_seed0_sim_exposition_digest():
    reg = _golden_registry()
    assert reg.digest() == GOLDEN_SIM_DIGEST
    # And the exposition it hashes contains the headline families.
    text = reg.exposition(include_wall=False)
    for family in ("repro_source_events_total", "repro_sink_events_total",
                   "repro_sink_latency_seconds", "repro_module_events_total",
                   "repro_trace_spans_total"):
        assert family in text, family
    # Fresh in-process run (shifted event-id base): still bit-identical.
    assert _golden_registry().digest() == GOLDEN_SIM_DIGEST


def test_sim_metrics_bit_identical_across_journal_restore():
    """Gate (b): kill the driver mid-run, restore from the journal, replay
    — the SIM exposition (and digest) match the uninterrupted run."""
    from repro.query import MultiQueryScenario
    from repro.serving.journal import Journal
    from repro.sim.dynamism import DynamismSpec, HostCrash

    def _cfg():
        return ScenarioConfig(
            num_cameras=60, duration_s=60.0, seed=0,
            dynamism=DynamismSpec(perturbations=(
                HostCrash(hosts=("node0",), t_start=20.0, outage_s=10.0),)),
        )

    ref = MultiQueryScenario(_cfg(), 3, journal=Journal(15.0))
    ref_res = ref.run()
    crashed = MultiQueryScenario(_cfg(), 3, journal=Journal(15.0))
    crashed.run_until(50.0)  # killed here — after the t=45 snapshot
    wal = crashed.journal
    del crashed
    rec = MultiQueryScenario(_cfg(), 3, journal=Journal(15.0))
    rec.restore(wal)
    rec_res = rec.run()

    r_ref, r_rec = MetricsRegistry(), MetricsRegistry()
    ref.publish_metrics(r_ref, ref_res)
    rec.publish_metrics(r_rec, rec_res)
    assert r_ref.exposition(include_wall=False) == r_rec.exposition(
        include_wall=False
    )
    assert r_ref.digest() == r_rec.digest()
    # The journal-integrated counters are part of the digested surface.
    assert "repro_journal_records_total" in r_ref.exposition(
        include_wall=False
    )


def test_sim_metrics_bit_identical_across_mesh_widths():
    """Gate (c): identical SIM expositions for the 1-, 2- and 8-way
    device runs of the same seed-0 workload (wall-domain attribution —
    shards_used, engine info — may differ and is excluded)."""
    jax = pytest.importorskip("jax")
    if len(jax.devices()) < 8:
        pytest.skip("needs 8 devices")
    from repro.distributed import camera_mesh
    from repro.query import MultiQueryScenario, QuerySpec

    base = dict(num_cameras=60, duration_s=60.0, seed=0, tl="bfs",
                batching="dynamic", m_max=25, engine="megastep")
    specs = [QuerySpec(tl="wbfs"), QuerySpec(tl="bfs", tl_peak_speed=6.0)]

    expositions = {}
    for n in (1, 2, 8):
        cfg = ScenarioConfig(**base)
        kw = {"mesh": camera_mesh(jax.devices()[:n])} if n > 1 else {}
        scn = MultiQueryScenario(cfg, copy.deepcopy(specs), **kw)
        res = scn.run()
        assert scn.engine_used.startswith("megastep"), scn.engine_fallback_reason
        assert scn.shards_used == n
        reg = MetricsRegistry()
        scn.publish_metrics(reg, res)
        expositions[n] = reg.exposition(include_wall=False)
        # Shard attribution is exported, but wall-domain only.
        full = reg.exposition(include_wall=True)
        assert "repro_engine_shards_used" in full
        assert "repro_engine_shards_used" not in expositions[n]
    assert expositions[1] == expositions[2] == expositions[8]
