"""Prefill + step-by-step decode must equal the teacher-forced forward
(KV-cache correctness) for a representative arch of every family."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_config
from repro.models import decode, forward, init_cache, init_params, prefill, reduced_config

KEY = jax.random.PRNGKey(0)
B, S, EXTRA = 2, 32, 3

ARCHS = [
    "llama3.2-1b",        # dense GQA
    "qwen3-8b",           # qk_norm
    "mamba2-1.3b",        # SSM
    "hymba-1.5b",         # hybrid + meta tokens + SWA ring cache
    "deepseek-v2-lite-16b",  # MLA + MoE
    "qwen2-moe-a2.7b",    # MoE
    "whisper-large-v3",   # enc-dec + cross attention
]


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_matches_forward(arch):
    cfg = reduced_config(get_config(arch))
    params = init_params(KEY, cfg)
    tokens = jax.random.randint(KEY, (B, S + EXTRA), 0, cfg.vocab_size)
    max_len = S + EXTRA + cfg.meta_tokens + 2

    if cfg.arch_type == "encdec":
        frames = jax.random.normal(KEY, (B, cfg.encoder_seq, cfg.d_model)) * 0.02
        batch_full = {"frames": frames, "tokens": tokens}
        batch_pref = {"frames": frames, "tokens": tokens[:, :S]}
    else:
        batch_full = {"tokens": tokens}
        batch_pref = {"tokens": tokens[:, :S]}

    logits_full, _ = forward(params, cfg, batch_full)
    cache = init_cache(cfg, B, max_len, dtype=jnp.float32)
    logits_pref, cache = prefill(params, cfg, batch_pref, cache)
    np.testing.assert_allclose(
        np.asarray(logits_pref[:, -1, : cfg.vocab_size], np.float32),
        np.asarray(logits_full[:, S - 1, : cfg.vocab_size], np.float32),
        rtol=2e-2,
        atol=2e-2,
    )
    cache_len = jnp.asarray(S + cfg.meta_tokens, jnp.int32)
    for i in range(EXTRA):
        tok = tokens[:, S + i][:, None]
        logits_dec, cache = decode(params, cfg, tok, cache, cache_len)
        cache_len = cache_len + 1
        np.testing.assert_allclose(
            np.asarray(logits_dec[:, -1, : cfg.vocab_size], np.float32),
            np.asarray(logits_full[:, S + i, : cfg.vocab_size], np.float32),
            rtol=3e-2,
            atol=3e-2,
        )


def test_mla_absorbed_equals_naive():
    from repro.models.mla import init_mla, init_mla_cache, mla_decode, mla_prefill

    cfg = reduced_config(get_config("deepseek-v2-lite-16b"))
    params = init_mla(jax.random.PRNGKey(3), cfg)
    x = jax.random.normal(KEY, (B, S + 1, cfg.d_model)) * 0.5
    pos = jnp.broadcast_to(jnp.arange(S + 1, dtype=jnp.int32), (B, S + 1))
    cache = init_mla_cache(cfg, B, S + 2, jnp.float32)
    _, cache = mla_prefill(params, x[:, :S], cfg, pos[:, :S], cache)
    cl = jnp.asarray(S, jnp.int32)
    y_abs, _ = mla_decode(params, x[:, S : S + 1], cfg, cache, cl, absorb=True)
    y_nav, _ = mla_decode(params, x[:, S : S + 1], cfg, cache, cl, absorb=False)
    np.testing.assert_allclose(np.asarray(y_abs), np.asarray(y_nav), atol=1e-4)


def test_sliding_window_ring_cache_long_decode():
    """Decode past the ring-cache capacity: the SWA ring must keep matching
    the full forward (window semantics, ring overwrite)."""
    import dataclasses

    cfg = reduced_config(get_config("llama3.2-1b"))
    cfg = dataclasses.replace(cfg, sliding_window=16)
    params = init_params(KEY, cfg)
    total = 48  # 3x the window
    tokens = jax.random.randint(KEY, (B, total), 0, cfg.vocab_size)
    logits_full, _ = forward(params, cfg, {"tokens": tokens})

    prefill_len = 24  # > window: exercises the ring-tail prefill write
    cache = init_cache(cfg, B, prefill_len + (total - prefill_len), dtype=jnp.float32)
    # ring caches are window-sized:
    _, cache = prefill(params, cfg, {"tokens": tokens[:, :prefill_len]}, cache)
    cache_len = jnp.asarray(prefill_len, jnp.int32)
    for i in range(prefill_len, total):
        logits_dec, cache = decode(params, cfg, tokens[:, i][:, None], cache, cache_len)
        cache_len = cache_len + 1
        np.testing.assert_allclose(
            np.asarray(logits_dec[:, -1, : cfg.vocab_size], np.float32),
            np.asarray(logits_full[:, i, : cfg.vocab_size], np.float32),
            rtol=3e-2,
            atol=3e-2,
        )
