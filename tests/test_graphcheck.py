"""Compile-time dataflow-graph verifier gate (GRF rules).

A deliberately miswired app must be *rejected at compile time with a
readable diagnostic*; a well-formed app must verify clean, pre- and
post-run, on both the interpreted and mega-step paths.
"""

import copy

import pytest

from repro.analysis import GraphContractError, verify_compiled, verify_megastep
from repro.analysis.graphcheck import check_compiled
from repro.core.compile import DeploymentSpec, compile_app
from repro.core.dataflow import ModuleSpec, TrackingApp, fc_is_active
from repro.query import MultiQueryScenario, QuerySpec
from repro.sim import ScenarioConfig, TrackingScenario


def _scenario(**kw):
    base = dict(num_cameras=60, duration_s=5.0, seed=0, tl="bfs")
    base.update(kw)
    return TrackingScenario(ScenarioConfig(**base))


def test_well_formed_app_verifies_clean():
    assert verify_compiled(_scenario().compiled) == []


def test_grf001_dangling_stage_rejected():
    compiled = _scenario().compiled
    compiled.va_tasks[0].downstream.clear()
    findings = verify_compiled(compiled)
    assert any(f.rule == "GRF001" for f in findings)
    msg = next(f.message for f in findings if f.rule == "GRF001")
    assert "VA-0" in msg and "downstream" in msg


def test_grf001_route_to_missing_task_rejected():
    compiled = _scenario().compiled
    compiled._cr_route[next(iter(compiled._cr_route))] = "CR-404"
    findings = verify_compiled(compiled)
    assert any(f.rule == "GRF001" and "CR-404" in f.message for f in findings)


def test_grf002_undeclared_feedback_cycle_named_in_diagnostic():
    compiled = _scenario().compiled
    # Close an event-edge loop CR -> VA (the only sanctioned loop closure is
    # the QF state push, which never appears as a downstream edge).
    compiled.cr_tasks[0].downstream[compiled.va_tasks[0].name] = compiled.va_tasks[0]
    findings = verify_compiled(compiled)
    cyc = [f for f in findings if f.rule == "GRF002"]
    assert cyc, findings
    assert "->" in cyc[0].message and "QF" in cyc[0].message


def test_grf003_fused_task_under_dynamic_xi_rejected():
    scn = _scenario()
    assert verify_compiled(scn.compiled) == []
    # Force the inconsistent state GRF003 exists for: a compute perturbation
    # landing after the pipeline was built (bypasses the setter's guard),
    # leaving fused tasks under a dynamic xi.
    scn.sim._xi_multiplier = lambda host, t: 1.0
    findings = verify_compiled(scn.compiled)
    assert any(f.rule == "GRF003" and "xi" in f.message for f in findings)


def test_grf004_unknown_module_spec_rejected_via_compile_hook():
    scn = _scenario()  # donor world/sim with valid geometry
    app = scn.cfg.to_app()
    app.specs["XX"] = ModuleSpec()
    with pytest.raises(GraphContractError) as ei:
        compile_app(app, scn.world, scn.cfg.deployment(), scn.sim, verify=True)
    text = str(ei.value)
    assert "GRF004" in text and "'XX'" in text
    # The diagnostic is one readable block: header with a count + bullets.
    assert text.splitlines()[0].startswith("compiled app violates")


def test_grf004_non_callable_logic_rejected():
    scn = _scenario()
    app = scn.cfg.to_app()
    app.va = None
    findings = verify_compiled(
        compile_app(app, scn.world, scn.cfg.deployment(), scn.sim)
    )
    assert any(f.rule == "GRF004" and "app.va" in f.message for f in findings)


def test_env_hook_verifies_every_compile(monkeypatch):
    monkeypatch.setenv("REPRO_ANALYSIS_VERIFY", "1")
    scn = _scenario()  # well-formed: compiles under the hook
    assert verify_compiled(scn.compiled) == []
    app = scn.cfg.to_app()
    app.specs["XX"] = ModuleSpec()
    with pytest.raises(GraphContractError):
        compile_app(app, scn.world, scn.cfg.deployment(), scn.sim)


def test_check_compiled_passes_silently_on_good_graph():
    check_compiled(_scenario().compiled)  # must not raise


# --------------------------------------------------------------------- #
# GRF005 — mega-step totality                                            #
# --------------------------------------------------------------------- #
MQ_BASE = dict(num_cameras=60, duration_s=10.0, seed=0, tl="bfs",
               batching="dynamic", m_max=25)


def _mq(engine="megastep", **kw):
    cfg = ScenarioConfig(**{**MQ_BASE, **kw})
    cfg.engine = engine
    return MultiQueryScenario(cfg, [QuerySpec(tl="bfs")])


def test_grf005_eligible_megastep_config_verifies_clean():
    scn = _mq()
    assert verify_megastep(scn) == []
    scn.run()
    assert verify_megastep(scn, post_run=True) == []
    assert scn.engine_used.startswith("megastep")


def test_grf005_fallback_with_reason_verifies_clean():
    scn = _mq(embed_dim=8)  # ineligible: embed plane keeps the interpreter
    assert verify_megastep(scn) == []
    scn.run()
    assert verify_megastep(scn, post_run=True) == []
    assert scn.engine_used == "interpreted"
    assert scn.engine_fallback_reason == "embed_dim"


def test_grf005_rejects_unobservable_no_backend_no_reason(monkeypatch):
    scn = _mq()
    import repro.core.megastep as ms

    monkeypatch.setattr(ms, "megastep_backend", lambda s: (None, ""))
    findings = verify_megastep(scn)
    assert [f.rule for f in findings] == ["GRF005"]
    assert "engine_fallback_reason" in findings[0].message


def test_grf005_interpreted_engine_is_out_of_scope():
    scn = _mq(engine="interpreted")
    assert verify_megastep(scn) == []


def test_grf005_post_run_rejects_silent_interpreted_fallback():
    scn = _mq()
    scn.run()
    scn.engine_used = "interpreted"
    scn.engine_fallback_reason = ""
    findings = verify_megastep(scn, post_run=True)
    assert any("no engine_fallback_reason" in f.message for f in findings)
