"""Spotlight-search equivalence + scale regression (perf PR acceptance).

* The incremental :class:`ResumableDijkstra` must match the from-scratch
  ``weighted_ball`` exactly, across growing radii and restart episodes.
* The batched CSR relaxation (``spotlight_ball`` ref path, run in x64) must
  match the pure-Python Dijkstra ball bit-exactly on 100 random queries.
* The Pallas kernel step (interpret mode) must match the jnp reference
  exactly (min-plus is rounding-free under tiling).
* A 10k-camera scenario must build + run within a wall-clock ceiling.
"""

import math
import time

import numpy as np
import pytest

from repro.core.roadnet import ResumableDijkstra, make_road_network
from repro.core.tracking import Detection, TLProbabilistic, TLWBFS


@pytest.fixture(scope="module")
def road():
    return make_road_network(num_vertices=200, target_edges=560, seed=5)


# --------------------------------------------------------------------- #
# Incremental Dijkstra == from-scratch weighted ball                     #
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_resumable_matches_weighted_ball(seed):
    net = make_road_network(num_vertices=150, target_edges=420, seed=seed)
    rng = np.random.default_rng(seed)
    for src in rng.integers(0, 150, size=5):
        search = ResumableDijkstra(net, int(src))
        for radius in np.cumsum(rng.uniform(20.0, 400.0, size=6)):
            incremental = search.ball(float(radius))
            scratch = net.weighted_ball(int(src), float(radius))
            assert incremental == scratch


def test_resumable_settle_order_is_nondecreasing(road):
    search = ResumableDijkstra(road, 0)
    ball = search.ball(5000.0)
    dists = [ball[v] for v in search.order]
    assert all(a <= b for a, b in zip(dists, dists[1:]))


def test_csr_roundtrip(road):
    indptr, indices, weights = road.csr()
    assert indptr[-1] == sum(len(n) for n in road.adjacency)
    for v in range(road.num_vertices):
        nbrs = [(int(indices[i]), float(weights[i])) for i in range(indptr[v], indptr[v + 1])]
        assert nbrs == road.adjacency[v]


# --------------------------------------------------------------------- #
# Batched CSR relaxation == pure-Python Dijkstra (bit-exact in x64)      #
# --------------------------------------------------------------------- #
def test_spotlight_ball_ref_bit_exact_100_queries(road):
    jnp = pytest.importorskip("jax.numpy")
    from jax.experimental import enable_x64

    from repro.kernels.spotlight_ball.ref import dense_adjacency, spotlight_ball_ref

    indptr, indices, weights = road.csr()
    rng = np.random.default_rng(0)
    Q = 100
    sources = rng.integers(0, road.num_vertices, size=Q).astype(np.int32)
    radii = rng.uniform(50.0, 2000.0, size=Q)

    with enable_x64():
        W = jnp.asarray(dense_adjacency(indptr, indices, weights))
        D = np.asarray(spotlight_ball_ref(W, jnp.asarray(sources), jnp.asarray(radii)))

    for qi in range(Q):
        ball = road.weighted_ball(int(sources[qi]), float(radii[qi]))
        row = D[qi]
        inside = {v for v in range(road.num_vertices) if math.isfinite(row[v])}
        assert inside == set(ball), f"membership mismatch for query {qi}"
        for v, d in ball.items():
            assert row[v] == d, f"distance mismatch at query {qi}, vertex {v}"


def test_spotlight_ball_pallas_matches_ref(road):
    jnp = pytest.importorskip("jax.numpy")
    from repro.kernels.spotlight_ball.kernel import relax_step_pallas
    from repro.kernels.spotlight_ball.ref import dense_adjacency, relax_step_ref

    indptr, indices, weights = road.csr()
    W = jnp.asarray(dense_adjacency(indptr, indices, weights.astype(np.float32)))
    rng = np.random.default_rng(1)
    Q = 16
    D = jnp.asarray(
        np.where(rng.uniform(size=(Q, road.num_vertices)) < 0.05, 0.0, np.inf).astype(
            np.float32
        )
    )
    for _ in range(3):
        ref_step = relax_step_ref(D, W)
        pallas_step = relax_step_pallas(D, W, interpret=True)
        np.testing.assert_array_equal(np.asarray(pallas_step), np.asarray(ref_step))
        D = ref_step


# --------------------------------------------------------------------- #
# Incremental TL strategies == original from-scratch behaviour           #
# --------------------------------------------------------------------- #
def test_wbfs_incremental_across_episodes(road):
    cams = {c: c for c in range(road.num_vertices)}
    incremental = TLWBFS(road, cams, entity_speed=4.0)
    for episode_start, cam in ((0.0, 10), (40.0, 55), (90.0, 10)):
        det = [Detection(camera_id=cam, positive=True, timestamp=episode_start)]
        incremental.update(det, now=episode_start)
        fresh = TLWBFS(road, cams, entity_speed=4.0)
        fresh.update(det, now=episode_start)
        for dt in (3.0, 9.0, 21.0, 33.0):
            now = episode_start + dt
            assert incremental.update([], now) == fresh.update([], now)


def test_multi_source_spotlight_dedupes_duplicate_sources():
    """Two queries sharing a blind-spot camera used to pad duplicate rows
    into the kernel call; duplicates must now collapse before dispatch
    (9 rows / 2 unique pairs -> the minimum bucket, never 16) while the
    returned per-query sets stay equal to independent singleton calls."""
    pytest.importorskip("jax")
    from repro.core.tracking import multi_source_spotlight
    from repro.kernels import dispatch

    net = make_road_network(num_vertices=160, target_edges=440, seed=11)
    cams = {c: c for c in range(net.num_vertices)}
    sources = [5, 5, 5, 80, 80, 5, 80, 5, 5]
    radii = [300.0] * len(sources)
    for coverage in (None, 0.9):
        out = multi_source_spotlight(net, cams, sources, radii, coverage=coverage)
        solo = {
            s: multi_source_spotlight(net, cams, [s], [300.0], coverage=coverage)[0]
            for s in (5, 80)
        }
        assert len(out) == len(sources)
        for s, got in zip(sources, out):
            assert got == solo[s] and got
    # Distinct set objects per row: mutating one must not leak into others.
    out[0].add(-1)
    assert -1 not in out[5]
    # Bucket accounting: this network only ever dispatched the minimum
    # bucket (2 unique pairs), never the bucket for 9 raw rows.
    shapes = {s for s in dispatch._SHAPES if s[0] == "ball" and s[1] == net.num_vertices}
    assert shapes and all(s[2] == dispatch.BUCKET_MIN for s in shapes)


def test_multi_entity_python_vs_kernel(road):
    pytest.importorskip("jax")
    cams = {c: c for c in range(road.num_vertices)}
    tl = TLProbabilistic(road, cams, entity_speed=4.0, coverage=0.9)
    tl.track("a", 10, 0.0)
    tl.track("b", 150, 2.0)
    tl.track("c", 77, 5.0)
    py = tl.spotlight_multi(30.0)
    kr = tl.spotlight_multi(30.0, use_kernel=True)
    assert py == kr
    assert py  # non-empty


# --------------------------------------------------------------------- #
# Scale regression: 10k cameras must stay cheap                         #
# --------------------------------------------------------------------- #
def test_10k_camera_scenario_under_wall_clock_ceiling():
    from repro.sim import ScenarioConfig, TrackingScenario

    t0 = time.time()
    cfg = ScenarioConfig(
        num_cameras=10_000,
        duration_s=10.0,
        fps=1.0,
        tl="bfs",
        batching="dynamic",
        m_max=25,
        seed=0,
    )
    res = TrackingScenario(cfg).run()
    wall = time.time() - t0
    assert res.source_events > 0
    assert res.peak_active < 10_000, "spotlight must not light up every camera"
    # Generous CI ceiling; the seed-era O(num_cameras)-per-tick loops plus
    # O(V^2)-memory road construction would blow far past this.
    assert wall < 60.0, f"10k-camera scenario took {wall:.1f}s"
