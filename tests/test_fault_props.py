"""Property tests for the fault plane (PR 6).

Requires the optional ``hypothesis`` test dependency (skipped cleanly when
missing, like the other ``*_props`` modules).

Over random crash/partition schedules the serving plane must keep its
recovery guarantees: the journalled event stream is deterministic in the
inputs (a replay neither loses nor duplicates an event — record counts and
digests match exactly), no event is ever attributed to a dead query, every
per-query ledger reconciles exactly with ``dp_fault`` included, and traffic
converges again after the fault window heals.
"""

import pytest

hypothesis = pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st

from repro.query import MultiQueryScenario, QuerySpec
from repro.serving.journal import Journal
from repro.sim import ScenarioConfig
from repro.sim.dynamism import DynamismSpec, HostCrash, NetworkPartition

DURATION = 40.0

# One world key for every example: the process-wide world cache makes each
# hypothesis example pay scenario construction only, not geometry builds.
def _cfg(spec):
    return ScenarioConfig(num_cameras=100, duration_s=DURATION, seed=0,
                          tl="bfs", batching="dynamic", m_max=25,
                          dynamism=spec)


@st.composite
def fault_specs(draw):
    """0-2 crashes + 0-1 partitions, at least one perturbation, windows
    inside the run so retries can drain before the horizon."""
    perts = []
    for _ in range(draw(st.integers(0, 2))):
        t0 = draw(st.floats(5.0, 22.0, allow_nan=False))
        perts.append(
            HostCrash(
                hosts=(draw(st.sampled_from(["node0", "edge1", "edge2"])),),
                t_start=t0,
                outage_s=draw(st.floats(2.0, 8.0, allow_nan=False)),
            )
        )
    if draw(st.booleans()) or not perts:
        t0 = draw(st.floats(5.0, 22.0, allow_nan=False))
        perts.append(
            NetworkPartition(
                group_a=("node", "head"),
                group_b=("edge",),
                t_start=t0,
                t_end=t0 + draw(st.floats(2.0, 8.0, allow_nan=False)),
            )
        )
    return DynamismSpec(perturbations=tuple(perts))


@settings(max_examples=10, deadline=None, derandomize=True)
@given(spec=fault_specs())
def test_replay_never_loses_or_duplicates_events(spec):
    """Two builds from the same inputs journal the identical event stream:
    same record counts per kind (no loss, no duplication) and the same
    digest (same order, same payloads)."""
    a = MultiQueryScenario(_cfg(spec), 2, journal=Journal(10.0))
    a.run()
    b = MultiQueryScenario(_cfg(spec), 2, journal=Journal(10.0))
    b.run()
    assert a.journal.counts() == b.journal.counts()
    assert a.journal.digest() == b.journal.digest()


@settings(max_examples=10, deadline=None, derandomize=True)
@given(
    spec=fault_specs(),
    cancel_at=st.floats(8.0, 30.0, allow_nan=False),
)
def test_faults_never_attribute_to_dead_queries(spec, cancel_at):
    """Fault losses respect the lifecycle: a dead query's counters freeze —
    late completions AND late fault drops are orphan-accounted — and every
    ledger reconciles exactly with ``dp_fault`` in the books."""
    specs = [QuerySpec(), QuerySpec(submit_at=2.0, cancel_at=cancel_at)]
    res = MultiQueryScenario(_cfg(spec), specs).run()
    for qid, st_q in res.registry.states.items():
        assert (
            st_q.sourced
            == st_q.completed
            + st_q.dropped
            + st_q.orphan_completed
            + st_q.orphan_dropped
        ), (qid, spec)
        assert st_q.dropped == sum(st_q.dp[1:])
        if st_q.ended_at is not None:
            # Nothing attributed after death (orphans are the overflow).
            assert all(t <= st_q.ended_at for t, _ in st_q.latencies)


@settings(max_examples=8, deadline=None, derandomize=True)
@given(t0=st.floats(8.0, 16.0, allow_nan=False))
def test_traffic_converges_after_heal(t0):
    """After a partition heals, the pipeline drains and completes again:
    the live query sees sink completions past the window's end, and the
    fault plane stops charging losses."""
    heal = t0 + 6.0
    spec = DynamismSpec(
        perturbations=(
            NetworkPartition(
                group_a=("node", "head"), group_b=("edge",),
                t_start=t0, t_end=heal,
            ),
        )
    )
    sc = MultiQueryScenario(_cfg(spec), 1, journal=Journal(10.0))
    res = sc.run()
    st_q = res.registry.get(0)
    assert any(t > heal for t, _ in st_q.latencies), "no post-heal completions"
    # Fault losses only happen while a window is open (plus the retry tail):
    # drop records past heal + the longest possible retry chain would mean
    # the plane kept charging after recovery.
    fp = sc.sim.faults
    tail = heal + fp.retry.max_retries * (
        fp.retry.timeout_s + fp.retry.cap_s * (1.0 + fp.retry.jitter)
    )
    late = [
        t for kind, t, a, _ in sc.journal.records
        if kind == "drop" and a == 4.0 and t > tail
    ]
    assert late == [], late
