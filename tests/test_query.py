"""Multi-query tenancy plane (tentpole of the query-plane PR).

The correctness anchor is the **bit-exactness harness**: with interference
disabled (admission off; identical queries submitted at t=0, so the union
spotlight equals each query's own and no query perturbs another's event
stream), a fused N-query run's *per-query* summaries must be bit-identical
to N independent single-query ``TrackingScenario`` runs at seed 0 — drops
off AND drops on.  The solo summaries are frozen below as goldens (mirroring
``tests/test_dynamism.py``), so a drift in either the solo engine or the
fused plane fails loudly.

Also covered: lifecycle (submit/cancel/ttl + orphan accounting), per-query
drop charging through the pipeline drop hook, the kernel union-spotlight
path, the query-major re-id dispatch, admission control, per-query
telemetry rows, and the QueryCase sweep integration.
"""

import math
import os

import numpy as np
import pytest

# Full-duration kernel-spotlight goldens run under REPRO_RUN_SLOW=1; the
# shortened-horizon equivalents below keep the same code paths in tier-1
# (see PERF.md §PR-9 for the wall-time budget).
slow = pytest.mark.skipif(
    os.environ.get("REPRO_RUN_SLOW", "") != "1",
    reason="full-duration golden replay; set REPRO_RUN_SLOW=1",
)

from repro.query import (
    AdmissionController,
    AdmissionPolicy,
    MultiQueryScenario,
    QueryRegistry,
    QuerySpec,
    run_queries_serial,
)
from repro.sim import QueryCase, ScenarioConfig, SweepRunner, TrackingScenario

# --------------------------------------------------------------------- #
# Frozen goldens: the solo summaries every fused per-query view must     #
# reproduce bit-for-bit (seed 0, 300 cameras, 150 s, TL-BFS, dynamic).   #
# --------------------------------------------------------------------- #
GOLDEN_NODROP = {
    "source_events": 1662, "on_time": 1662, "delayed": 0, "dropped": 0,
    "delayed_frac": 0.0, "dropped_frac": 0.0, "median_latency_s": 0.157,
    "p99_latency_s": 0.517, "peak_active": 25,
    "positives_generated": 31, "positives_completed": 23,
}
GOLDEN_DROPS = {
    "source_events": 3355, "on_time": 2564, "delayed": 0, "dropped": 791,
    "delayed_frac": 0.0, "dropped_frac": 0.2358, "median_latency_s": 4.669,
    "p99_latency_s": 14.082, "peak_active": 63,
    "positives_generated": 31, "positives_completed": 23,
}


def _cfg(**kw):
    base = dict(num_cameras=300, duration_s=150.0, seed=0, tl="bfs",
                batching="dynamic", m_max=25)
    base.update(kw)
    return ScenarioConfig(**base)


def _drops_cfg():
    return _cfg(tl_peak_speed=7.0, num_va=5, num_cr=5,
                drops_enabled=True, avoid_drop_positives=True)


# --------------------------------------------------------------------- #
# Bit-exactness harness                                                  #
# --------------------------------------------------------------------- #
def test_fused_nodrop_bit_identical_to_solo_golden():
    cfg = _cfg()
    assert TrackingScenario(cfg).run().summary() == GOLDEN_NODROP
    res = MultiQueryScenario(cfg, 3).run()
    for qid in res.per_query:
        assert res.per_query_summary(qid) == GOLDEN_NODROP
    # The shared pipeline ran the workload once: global == per-query view.
    assert res.result.summary() == GOLDEN_NODROP
    assert res.summary()["per_query_sourced_sum"] == 3 * GOLDEN_NODROP["source_events"]


def test_fused_drops_bit_identical_to_solo_golden():
    """Per-query drop charging (the compiled app's drop hook) reconciles
    bit-for-bit with the solo run's task-level drop accounting."""
    cfg = _drops_cfg()
    assert TrackingScenario(cfg).run().summary() == GOLDEN_DROPS
    res = MultiQueryScenario(cfg, 2).run()
    for qid in res.per_query:
        assert res.per_query_summary(qid) == GOLDEN_DROPS
    # Every drop was charged to every (identical) query at some drop point.
    for st in res.registry.states.values():
        assert st.dp[1] + st.dp[2] + st.dp[3] == GOLDEN_DROPS["dropped"]


def test_fused_matches_fresh_serial_baseline():
    """Beyond the frozen dict: the fused per-query views equal freshly-run
    independent single-query scenarios, the per-query-serial baseline."""
    cfg = _cfg(duration_s=60.0)
    serial, _wall = run_queries_serial(cfg, 2)
    res = MultiQueryScenario(cfg, 2).run()
    for i, qid in enumerate(sorted(res.per_query)):
        assert res.per_query_summary(qid) == serial[i].summary()


def test_serial_baseline_honours_coverage_and_warm_start_overrides():
    """run_queries_serial must run the SAME query the fused plane does —
    including the overrides ScenarioConfig cannot express (coverage,
    last_seen_camera warm start): a single-spec fused run stays
    bit-identical to its serial baseline for each of them."""
    cfg = ScenarioConfig(num_cameras=150, duration_s=40.0, seed=0, tl="prob")
    for spec in (
        QuerySpec(coverage=0.5),
        QuerySpec(tl="wbfs", last_seen_camera=100),
    ):
        serial, _ = run_queries_serial(cfg, [spec])
        res = MultiQueryScenario(cfg, [spec]).run()
        assert res.per_query_summary(0) == serial[0].summary(), spec


def test_trace_peak_queue_ignores_per_query_rows():
    """A Q:<id> row's 'queue' is that query's whole-pipeline in-flight
    count; it must not leak into the trace summary's task-queue peak."""
    from repro.sim import ComputeSlowdown, DynamismSpec

    spec = DynamismSpec((ComputeSlowdown(10.0, 20.0, 4.0, hosts=("node",)),))
    cfg = ScenarioConfig(num_cameras=100, duration_s=40.0, seed=0, tl="bfs",
                         drops_enabled=True, dynamism=spec)
    tr = MultiQueryScenario(cfg, 2).run().result.trace
    task_peak = max(
        max(row["queue"])
        for name, row in tr.series.items()
        if not name.startswith("Q:")
    )
    q_peak = max(max(tr.series[n]["queue"]) for n in tr.tasks("Q:"))
    assert q_peak > task_peak  # the pollution the fix guards against
    assert tr.summary()["peak_queue"] == task_peak


def test_union_dedup_sources_once_per_camera():
    """N queries, one pipeline: global source_events equals the solo count
    (each union camera sources one frame per tick), while per-query sourced
    counters see the full per-query stream."""
    cfg = _cfg(duration_s=60.0)
    solo_events = TrackingScenario(cfg).run().summary()["source_events"]
    res = MultiQueryScenario(cfg, 4).run()
    assert res.result.source_events == solo_events
    for st in res.registry.states.values():
        assert st.sourced == solo_events


# --------------------------------------------------------------------- #
# Lifecycle: submit / cancel / ttl, orphan accounting                    #
# --------------------------------------------------------------------- #
def test_lifecycle_submit_cancel_ttl():
    cfg = ScenarioConfig(num_cameras=200, duration_s=50.0, seed=0, tl="wbfs")
    specs = [
        QuerySpec(submit_at=0.0),
        QuerySpec(submit_at=10.0, cancel_at=30.0),
        QuerySpec(submit_at=20.0, ttl_s=5.0, tl_peak_speed=2.0,
                  last_seen_camera=150),
    ]
    res = MultiQueryScenario(cfg, specs).run()
    assert res.states == {0: "found", 1: "cancelled", 2: "expired"}
    reg = res.registry
    st1 = reg.get(1)
    assert st1.scoped_at == pytest.approx(10.0)
    assert st1.ended_at == pytest.approx(30.0)
    # A cancelled query keeps no cameras: its applied set drained.
    assert st1.applied == set()
    # Full reconciliation: nothing unaccounted after the drain window.
    for qid, row in reg.reconcile().items():
        assert row["unaccounted"] == 0, (qid, row)
    # No event is attributed to a query after it ended (orphans only).
    assert all(t <= 30.0 for t, _ in st1.latencies)
    # found is a one-way transition with a timestamp.
    assert reg.get(0).found_at is not None
    assert reg.get(0).found_at <= reg.get(0).latencies[-1][0]


def test_found_queries_survive_ttl():
    """ttl bounds the *search*: a query that found its entity keeps going."""
    cfg = _cfg(duration_s=40.0)
    res = MultiQueryScenario(cfg, [QuerySpec(ttl_s=20.0)]).run()
    assert res.states[0] == "found"
    assert res.registry.get(0).ended_at is None


def test_late_submission_seeds_from_entity_position():
    """A query submitted mid-run starts its spotlight at the entity's
    current neighborhood and still converges to found."""
    cfg = _cfg(duration_s=80.0)
    res = MultiQueryScenario(cfg, [QuerySpec(), QuerySpec(submit_at=40.0)]).run()
    assert res.states == {0: "found", 1: "found"}
    st = res.registry.get(1)
    assert st.scoped_at == pytest.approx(40.0)
    assert st.sourced > 0
    assert all(t >= 40.0 for t, _ in st.latencies)


# --------------------------------------------------------------------- #
# Union spotlight: kernel mode == per-query mode                         #
# --------------------------------------------------------------------- #
def test_kernel_spotlight_mode_bit_equal_for_wbfs():
    """Shortened-horizon tier-1 version of the full-duration golden below."""
    cfg = ScenarioConfig(num_cameras=120, duration_s=25.0, seed=0, tl="wbfs")
    specs = [QuerySpec(), QuerySpec(submit_at=10.0, tl_peak_speed=6.0,
                                    last_seen_camera=80)]
    a = MultiQueryScenario(cfg, specs).run()
    b = MultiQueryScenario(cfg, specs, spotlight_mode="kernel").run()
    assert a.result.summary() == b.result.summary()
    for qid in a.per_query:
        assert a.per_query_summary(qid) == b.per_query_summary(qid)


@pytest.mark.slow
@slow
def test_kernel_spotlight_mode_bit_equal_for_wbfs_full_duration():
    cfg = ScenarioConfig(num_cameras=200, duration_s=50.0, seed=0, tl="wbfs")
    specs = [QuerySpec(), QuerySpec(submit_at=10.0, tl_peak_speed=6.0,
                                    last_seen_camera=120)]
    a = MultiQueryScenario(cfg, specs).run()
    b = MultiQueryScenario(cfg, specs, spotlight_mode="kernel").run()
    assert a.result.summary() == b.result.summary()
    for qid in a.per_query:
        assert a.per_query_summary(qid) == b.per_query_summary(qid)


def test_kernel_spotlight_mode_rejects_hop_ball_tls():
    cfg = _cfg(tl="bfs")
    with pytest.raises(ValueError, match="weighted-ball"):
        MultiQueryScenario(cfg, 1, spotlight_mode="kernel")
    with pytest.raises(ValueError, match="spotlight_mode"):
        MultiQueryScenario(cfg, 1, spotlight_mode="warp")


def test_kernel_spotlight_mode_with_probabilistic_coverage_groups():
    """Mixed wbfs + prob queries in kernel mode: the blind-spot balls group
    by coverage, each group one multi-source dispatch, and the prob query's
    active sets match its own per-query-mode run.  Shortened-horizon tier-1
    version of the full-duration golden below."""
    cfg = ScenarioConfig(num_cameras=80, duration_s=15.0, seed=0, tl="prob")
    specs = [QuerySpec(), QuerySpec(tl="wbfs", tl_peak_speed=6.0,
                                    last_seen_camera=70),
             QuerySpec(coverage=0.8, last_seen_camera=50)]
    a = MultiQueryScenario(cfg, specs).run()
    b = MultiQueryScenario(cfg, specs, spotlight_mode="kernel").run()
    assert a.result.summary() == b.result.summary()
    for qid in a.per_query:
        assert a.per_query_summary(qid) == b.per_query_summary(qid)


@pytest.mark.slow
@slow
def test_kernel_spotlight_mode_with_probabilistic_coverage_groups_full_duration():
    cfg = ScenarioConfig(num_cameras=150, duration_s=40.0, seed=0, tl="prob")
    specs = [QuerySpec(), QuerySpec(tl="wbfs", tl_peak_speed=6.0,
                                    last_seen_camera=100),
             QuerySpec(coverage=0.8, last_seen_camera=50)]
    a = MultiQueryScenario(cfg, specs).run()
    b = MultiQueryScenario(cfg, specs, spotlight_mode="kernel").run()
    assert a.result.summary() == b.result.summary()
    for qid in a.per_query:
        assert a.per_query_summary(qid) == b.per_query_summary(qid)


def test_programmatic_cancel_mid_run():
    """scenario.cancel(qid) is the API surface QuerySpec.cancel_at rides:
    calling it from a scheduled event ends the query identically."""
    cfg = _cfg(duration_s=40.0)
    scenario = MultiQueryScenario(cfg, 2)
    scenario.sim.schedule_at(15.0, scenario.cancel, 1, "user-abort")
    res = scenario.run()
    assert res.states == {0: "found", 1: "cancelled"}
    st = res.registry.get(1)
    assert st.reason == "user-abort"
    assert st.ended_at == pytest.approx(15.0)
    # Double-cancel and double-submit are idempotent no-ops.
    scenario.cancel(1)
    scenario._submit_query(0)
    assert res.registry.get(0).live


def test_probabilistic_shares_multi_source_ball_implementation():
    """The cleanup contract: TLProbabilistic.spotlight_multi's kernel path
    and the query plane's union spotlight run through ONE shared
    multi-source implementation, and it matches the incremental path."""
    from repro.core.roadnet import make_road_network
    from repro.core.tracking import TLProbabilistic, multi_source_spotlight

    net = make_road_network(num_vertices=150, target_edges=423, seed=3)
    cams = {c: c for c in range(0, 150, 2)}
    tl = TLProbabilistic(net, cams, entity_speed=4.0, coverage=0.9)
    for i in range(5):
        tl.track(f"e{i}", camera_id=(i * 31) % 150 // 2 * 2, timestamp=float(i))
    py = tl.spotlight_multi(40.0)
    tl._entity_searches.clear()
    kern = tl.spotlight_multi(40.0, use_kernel=True)
    assert py == kern
    # coverage=None returns the full ball - every camera the per-source
    # coverage sets could have chosen is inside it.
    items = list(tl.entities.items())
    full = multi_source_spotlight(
        net, cams,
        [v for _, (v, _) in items],
        [tl._entity_radius(t, 40.0) for _, (_, t) in items],
    )
    assert kern <= set().union(*full)


# --------------------------------------------------------------------- #
# Query-major fused re-ID                                                #
# --------------------------------------------------------------------- #
def test_reid_match_multi_bit_exact_vs_per_query_serial():
    from repro.kernels import dispatch

    rng = np.random.default_rng(7)
    g = rng.normal(size=(13, 32)).astype(np.float32)
    q = rng.normal(size=(5, 32)).astype(np.float32)
    mask = rng.uniform(size=(13, 5)) < 0.7
    s_f, m_f = dispatch.reid_match_multi(g, q, mask=mask, threshold=0.3)
    s_f, m_f = np.asarray(s_f), np.asarray(m_f)
    for j in range(5):
        rows = np.nonzero(mask[:, j])[0]
        if not len(rows):
            continue
        s1, m1 = dispatch.reid_match_multi(g[rows], q[j : j + 1], threshold=0.3)
        assert np.array_equal(np.asarray(s1)[:, 0], s_f[rows, j])
        assert np.array_equal(np.asarray(m1)[:, 0], m_f[rows, j])
    # Tenancy mask: pairs outside it can never match.
    assert np.all(np.isneginf(s_f[~mask]))
    assert not m_f[~mask].any()


def test_reid_match_multi_validates_shapes():
    from repro.kernels import dispatch

    with pytest.raises(ValueError, match="gallery"):
        dispatch.reid_match_multi(np.zeros(4), np.zeros((1, 4)))
    with pytest.raises(ValueError, match="queries"):
        dispatch.reid_match_multi(np.zeros((2, 4)), np.zeros((1, 5)))
    with pytest.raises(ValueError, match="mask"):
        dispatch.reid_match_multi(
            np.zeros((2, 4)), np.zeros((1, 4)), mask=np.ones((3, 1), bool)
        )


def test_fused_embed_path_counts_per_query_matches():
    """embed_dim > 0: one reid_match_multi dispatch per VA batch serves all
    live queries; the true-embedding query reproduces the solo matcher's
    count bit-for-bit, per-query counts stay separate."""
    from repro.kernels import dispatch

    cfg = _cfg(duration_s=40.0, embed_dim=16)
    solo = TrackingScenario(cfg).run()
    scenario = MultiQueryScenario(
        cfg, [QuerySpec(), QuerySpec(embedding_seed=99)]
    )
    dispatch.reset_stats()
    res = scenario.run()
    assert res.per_query_summary(0) == solo.summary()
    assert res.per_query[0].reid_matched == solo.reid_matched
    assert res.registry.get(1).embedding is not None
    # The live-query block stays device-resident across VA batches (the
    # registry caches one stacked array per live set).
    stats = dispatch.stats()
    assert stats["reid_multi_calls"] > 2
    assert stats["device_cache_hits"] > stats["device_cache_misses"]


# --------------------------------------------------------------------- #
# Admission control                                                      #
# --------------------------------------------------------------------- #
def test_admission_max_live_caps_and_queues():
    cfg = _cfg(duration_s=60.0)
    specs = [QuerySpec(submit_at=float(i)) for i in range(6)]
    res = MultiQueryScenario(
        cfg, specs, admission=AdmissionPolicy(max_live=2)
    ).run()
    assert res.summary()["queries_live_end"] == 2
    assert res.summary()["adm_queued"] == 4
    assert res.summary()["adm_queue_left"] == 4  # cap never frees up


def test_admission_hard_reject_mode():
    cfg = _cfg(duration_s=30.0)
    specs = [QuerySpec(submit_at=float(i)) for i in range(4)]
    res = MultiQueryScenario(
        cfg, specs,
        admission=AdmissionPolicy(max_live=1, queue_rejected=False),
    ).run()
    assert res.summary()["adm_rejected"] == 3
    rejected = [s for s in res.registry.states.values()
                if s.state == "cancelled"]
    assert len(rejected) == 3
    assert all(s.reason == "admission-rejected" for s in rejected)


def test_admission_beta_floor_blocks_and_recovers():
    """A degraded CR-tier budget queues submissions; once it recovers the
    queue drains on the control cadence."""

    class _Scenario:  # minimal duck type for the controller
        class app:
            gamma = 15.0

        _trace = None

        class compiled:
            va_tasks: list = []
            cr_tasks: list = []

    ctrl = AdmissionController(AdmissionPolicy(beta_floor=1.0))
    # No budget evidence (inf) -> admit.
    assert ctrl.decide(_Scenario, 0) == "admit"

    class _Budget:
        def __init__(self, v):
            self.v = v

        def min_budget(self):
            return self.v

    class _Task:
        name = "VA-0"

        def __init__(self, v):
            self.budget = _Budget(v)

    _Scenario.compiled.va_tasks = [_Task(0.2)]
    assert ctrl.decide(_Scenario, 0) == "queue"
    _Scenario.compiled.va_tasks = [_Task(5.0)]
    assert ctrl.admittable(_Scenario, 0)
    assert ctrl.decide(_Scenario, 0) == "admit"
    assert ctrl.decisions == {"admit": 2, "queue": 1, "reject": 0}


# --------------------------------------------------------------------- #
# Per-query telemetry + quality                                          #
# --------------------------------------------------------------------- #
def test_trace_gains_per_query_rows_and_quality():
    from repro.sim import ComputeSlowdown, DynamismSpec

    spec = DynamismSpec((ComputeSlowdown(20.0, 30.0, 4.0, hosts=("node",)),))
    cfg = _cfg(duration_s=60.0, drops_enabled=True,
               avoid_drop_positives=True, dynamism=spec)
    res = MultiQueryScenario(cfg, [QuerySpec(), QuerySpec(submit_at=25.0)]).run()
    trace = res.result.trace
    rows = trace.tasks("Q:")
    assert rows == ["Q:0", "Q:1"]
    from repro.sim.dynamism import TRACE_FIELDS

    n = len(trace.times)
    for name in rows:
        for f in TRACE_FIELDS:
            assert len(trace.series[name][f]) == n, (name, f)
    # Q:1 existed only from t=25: its earlier beta samples are backfilled inf.
    assert math.isinf(trace.series["Q:1"]["beta"][0])
    # executed is cumulative per query and reconciles with the registry.
    assert trace.series["Q:0"]["executed"][-1] == res.registry.get(0).completed
    # Per-query ground-truth quality rides each per-query result.
    q0 = res.per_query[0].quality
    assert set(q0) == {"truth_events", "track_recall", "track_precision"}
    assert 0.0 <= q0["track_recall"] <= 1.0


def test_per_query_quality_matches_solo_for_identical_queries():
    from repro.sim import DynamismSpec

    spec = DynamismSpec(())  # no perturbation: telemetry + quality only
    cfg = _cfg(duration_s=60.0, dynamism=spec)
    solo = TrackingScenario(cfg).run()
    res = MultiQueryScenario(cfg, 2).run()
    for qid in res.per_query:
        assert res.per_query[qid].quality == solo.quality


# --------------------------------------------------------------------- #
# Sweep + registry mechanics                                             #
# --------------------------------------------------------------------- #
def test_query_case_runs_through_sweep_runner():
    cfg = _cfg(duration_s=40.0)
    grid = [
        ("solo", cfg),
        ("fused4", QueryCase(queries=4, workload=cfg)),
    ]
    res = SweepRunner(mode="serial").run(grid)
    by_name = {r.name: r for r in res.records}
    assert by_name["fused4"].summary["queries"] == 4
    assert (
        by_name["fused4"].summary["source_events"]
        == by_name["solo"].summary["source_events"]
    )


def test_registry_bits_are_never_reused():
    reg = QueryRegistry()
    a = reg.register(QuerySpec())
    reg.mark(a, "cancelled", 0.0)
    b = reg.register(QuerySpec())
    assert a.bit != b.bit
    assert [s.query_id for s in reg.for_mask(a.bit | b.bit)] == [0, 1]


def test_registry_rejects_duplicate_ids_and_bad_states():
    reg = QueryRegistry()
    reg.register(QuerySpec(query_id=7))
    with pytest.raises(ValueError, match="already registered"):
        reg.register(QuerySpec(query_id=7))
    with pytest.raises(ValueError, match="unknown query state"):
        reg.mark(reg.get(7), "bogus", 0.0)


def test_normalize_queries_validation():
    from repro.query import normalize_queries

    assert len(normalize_queries(3)) == 3
    with pytest.raises(ValueError):
        normalize_queries(0)
    with pytest.raises(ValueError):
        normalize_queries([])
    with pytest.raises(TypeError):
        normalize_queries(["nope"])
